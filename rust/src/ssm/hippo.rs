//! HiPPO matrices and the S5 eigen-initialization, in Rust.
//!
//! Mirrors `python/compile/hippo.py` (paper §2.3, §4.2, Appendix B.1): the
//! HiPPO-LegS matrix, its normal component HiPPO-N = −½I + S (S skew-
//! symmetric), the low-rank correction, and the block-diagonal conjugate-
//! symmetric eigendecomposition used to initialize Λ, V, V⁻¹. The
//! decomposition goes through the Hermitian matrix i·S so the stable Jacobi
//! solver in [`crate::linalg`] applies.

use crate::linalg::{eigh, CMat};
use crate::num::C64;

/// HiPPO-LegS state matrix (paper eq. 7): lower-triangular, stiff, not
/// stably diagonalizable.
pub fn hippo_legs(n: usize) -> Vec<f64> {
    let q: Vec<f64> = (0..n).map(|i| (2.0 * i as f64 + 1.0).sqrt()).collect();
    let mut a = vec![0.0; n * n];
    for r in 0..n {
        for c in 0..n {
            a[r * n + c] = if r > c {
                -q[r] * q[c]
            } else if r == c {
                -(r as f64 + 1.0)
            } else {
                0.0
            };
        }
    }
    a
}

/// b_LegS input column (eq. 8).
pub fn legs_input_column(n: usize) -> Vec<f64> {
    (0..n).map(|i| (2.0 * i as f64 + 1.0).sqrt()).collect()
}

/// HiPPO-N, the normal component (eq. 11): −½I + skew-symmetric part.
pub fn hippo_normal(n: usize) -> Vec<f64> {
    let q: Vec<f64> = (0..n).map(|i| (i as f64 + 0.5).sqrt()).collect();
    let mut a = vec![0.0; n * n];
    for r in 0..n {
        for c in 0..n {
            a[r * n + c] = if r == c {
                -0.5
            } else if r < c {
                q[r] * q[c]
            } else {
                -q[r] * q[c]
            };
        }
    }
    a
}

/// Low-rank term P_LegS (eq. 12): A_LegS = HiPPO-N − P Pᵀ.
pub fn hippo_low_rank(n: usize) -> Vec<f64> {
    (0..n).map(|i| (i as f64 + 0.5).sqrt()).collect()
}

/// Stable eigendecomposition of HiPPO-N via the Hermitian matrix i·S.
///
/// Returns `(lam, v)` with HiPPO-N = V diag(λ) Vᴴ, eigenvalues sorted by
/// **descending imaginary part** (so conjugate partners mirror around the
/// middle), all with Re(λ) = −½.
pub fn eig_hippo_normal(n: usize) -> (Vec<C64>, CMat) {
    let a = hippo_normal(n);
    // skew part S = A + ½I; Hermitian H = i·S
    let h = CMat::from_fn(n, n, |r, c| {
        let s = a[r * n + c] + if r == c { 0.5 } else { 0.0 };
        C64::new(0.0, s) // i * s  (real s ⇒ purely imaginary entry)
    });
    let e = eigh(&h, 1e-13);
    // H = V diag(w) V^H with real w ⇒ S = V diag(-i w) V^H
    // ⇒ A = V diag(-1/2 - i w) V^H. eigh sorts w ascending ⇒ imag of λ
    // (-w) is descending, matching the Python ordering.
    let lam: Vec<C64> = e
        .eigenvalues
        .iter()
        .map(|&w| C64::new(-0.5, -w))
        .collect();
    (lam, e.vectors)
}

/// Block-diagonal HiPPO-N initialization with conjugate symmetry
/// (paper §3.2, Appendix B.1.1 / D.4). Mirrors
/// `hippo.block_diag_hippo_init` on the Python side.
///
/// Returns `(lam, v, vinv)`:
/// * `lam`: P2 = P/2 (or P) kept eigenvalues, Im > 0 half per block;
/// * `v`: (P × P2) block-diagonal eigenvector matrix;
/// * `vinv`: (P2 × P) = Vᴴ restricted to the kept columns.
pub fn block_diag_hippo_init(
    p: usize,
    j: usize,
    conj_sym: bool,
) -> (Vec<C64>, CMat, CMat) {
    assert!(p % j == 0, "latent size P={p} must be divisible by J={j}");
    let r = p / j;
    if conj_sym {
        assert!(r % 2 == 0, "block size R={r} must be even under conjugate symmetry");
    }
    let (lam_r, v_r) = eig_hippo_normal(r);
    let keep = if conj_sym { r / 2 } else { r };
    let p2 = keep * j;
    let mut lam = Vec::with_capacity(p2);
    for _ in 0..j {
        lam.extend_from_slice(&lam_r[..keep]);
    }
    let mut v = CMat::zeros(p, p2);
    for b in 0..j {
        for row in 0..r {
            for col in 0..keep {
                v[(b * r + row, b * keep + col)] = v_r[(row, col)];
            }
        }
    }
    let vinv = v.hermitian_t();
    (lam, v, vinv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn normal_matrix_is_normal() {
        for n in [2usize, 4, 8, 16] {
            let a = hippo_normal(n);
            // A Aᵀ == Aᵀ A
            let mut aat = vec![0.0; n * n];
            let mut ata = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    for k in 0..n {
                        aat[i * n + j] += a[i * n + k] * a[j * n + k];
                        ata[i * n + j] += a[k * n + i] * a[k * n + j];
                    }
                }
            }
            for k in 0..n * n {
                assert!((aat[k] - ata[k]).abs() < 1e-10, "n={n}");
            }
        }
    }

    #[test]
    fn legs_equals_normal_minus_low_rank() {
        let n = 8;
        let legs = hippo_legs(n);
        let norm = hippo_normal(n);
        let p = hippo_low_rank(n);
        for r in 0..n {
            for c in 0..n {
                let want = norm[r * n + c] - p[r] * p[c];
                assert!((legs[r * n + c] - want).abs() < 1e-12, "({r},{c})");
            }
        }
    }

    #[test]
    fn eig_reconstructs_hippo_normal() {
        let n = 16;
        let (lam, v) = eig_hippo_normal(n);
        let a = hippo_normal(n);
        // V diag(λ) Vᴴ == A
        let mut vd = v.clone();
        for i in 0..n {
            for jj in 0..n {
                vd[(i, jj)] = vd[(i, jj)] * lam[jj];
            }
        }
        let rec = vd.matmul(&v.hermitian_t());
        for r in 0..n {
            for c in 0..n {
                let want = C64::from_re(a[r * n + c]);
                assert!((rec[(r, c)] - want).abs() < 1e-8, "({r},{c})");
            }
        }
    }

    #[test]
    fn eigenvalues_have_real_part_minus_half() {
        let (lam, _) = eig_hippo_normal(32);
        for z in &lam {
            assert!((z.re + 0.5).abs() < 1e-10);
        }
        // descending imaginary parts
        for w in lam.windows(2) {
            assert!(w[0].im >= w[1].im - 1e-12);
        }
    }

    #[test]
    fn block_diag_shapes_and_positive_imag() {
        let (lam, v, vinv) = block_diag_hippo_init(32, 4, true);
        assert_eq!(lam.len(), 16);
        assert_eq!((v.rows, v.cols), (32, 16));
        assert_eq!((vinv.rows, vinv.cols), (16, 32));
        for z in &lam {
            assert!(z.im > 0.0);
        }
    }

    #[test]
    fn prop_block_diag_projection_identity() {
        // Vᴴ V = I on the kept subspace (V has orthonormal columns).
        prop::check("V^H V = I", 8, |g| {
            let j = 1 + g.below(4);
            let r = 2 * (1 + g.below(4));
            let p = j * r;
            let (_, v, vinv) = block_diag_hippo_init(p, j, true);
            let gram = vinv.matmul(&v);
            let p2 = v.cols;
            for i in 0..p2 {
                for jj in 0..p2 {
                    let want = if i == jj { 1.0 } else { 0.0 };
                    prop::close_f64(gram[(i, jj)].re, want, 1e-8)?;
                    prop::close_f64(gram[(i, jj)].im, 0.0, 1e-8)?;
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn rejects_bad_block_count() {
        block_diag_hippo_init(10, 3, false);
    }

    #[test]
    #[should_panic(expected = "even")]
    fn rejects_odd_block_with_conj_sym() {
        block_diag_hippo_init(9, 3, true);
    }
}
