//! Pure-Rust S5 layer and deep model (the L3 parity oracle).
//!
//! This mirrors `python/compile/model.py` operation-for-operation so the
//! compiled HLO can be checked bitwise-loosely (f32 tolerances) against an
//! independent implementation — and so the runtime benchmarks (Table 4,
//! Prop. 1) have an S5 subject whose inner loops we control.
//!
//! The layer (paper §3, §G.1):
//!   pre-LayerNorm → ZOH-discretized MIMO SSM via scan → y = 2·Re(C̃x̃) + D∘u
//!   → GELU → weighted-sigmoid gate → residual.

use crate::num::{C32, C64};
use crate::rng::Rng;
use crate::ssm::discretize::{discretize_diag, Method};
use crate::ssm::hippo;
use crate::ssm::scan;

/// Parameters of one S5 layer (conjugate-symmetric storage: P2 = P/2).
#[derive(Clone, Debug)]
pub struct S5Layer {
    /// Continuous-time eigenvalues Λ (length P2).
    pub lambda: Vec<C64>,
    /// Input matrix B̃ (P2 × H), row-major.
    pub b_tilde: Vec<C64>,
    /// Output matrices C̃ (n_dir × H × P2): 1 causal, 2 bidirectional.
    pub c_tilde: Vec<Vec<C64>>,
    /// Feedthrough D (H).
    pub d: Vec<f32>,
    /// log Δ (P2) — vector timescales (§4.3/D.5).
    pub log_dt: Vec<f32>,
    /// Weighted-sigmoid gate W (H × H).
    pub gate_w: Vec<f32>,
    /// LayerNorm scale/bias (H).
    pub norm_scale: Vec<f32>,
    pub norm_bias: Vec<f32>,
    pub h: usize,
    pub p2: usize,
}

/// Hyper-knobs for native initialization (mirrors `init_s5_layer`).
#[derive(Clone, Debug)]
pub struct S5Config {
    pub h: usize,
    pub p: usize,
    pub j: usize,
    pub conj_sym: bool,
    pub dt_min: f64,
    pub dt_max: f64,
    pub bidir: bool,
}

impl Default for S5Config {
    fn default() -> Self {
        S5Config { h: 32, p: 32, j: 1, conj_sym: true, dt_min: 1e-3, dt_max: 1e-1, bidir: false }
    }
}

impl S5Layer {
    /// HiPPO-N initialized layer (paper §3.2, B.1).
    pub fn init(cfg: &S5Config, rng: &mut Rng) -> S5Layer {
        let (lam, v, vinv) = hippo::block_diag_hippo_init(cfg.p, cfg.j, cfg.conj_sym);
        let p2 = lam.len();
        let h = cfg.h;
        // B sampled real (lecun normal) then rotated: B̃ = V⁻¹B.
        let mut b_tilde = vec![C64::ZERO; p2 * h];
        let scale_b = 1.0 / (h as f64).sqrt();
        let b_cols: Vec<f64> = (0..cfg.p * h).map(|_| rng.normal() * scale_b).collect();
        for r in 0..p2 {
            for c in 0..h {
                let mut acc = C64::ZERO;
                for k in 0..cfg.p {
                    acc += vinv[(r, k)].scale(b_cols[k * h + c]);
                }
                b_tilde[r * h + c] = acc;
            }
        }
        // C sampled complex then rotated: C̃ = C·V.
        let n_dir = if cfg.bidir { 2 } else { 1 };
        let scale_c = (0.5 / cfg.p as f64).sqrt();
        let mut c_tilde = Vec::with_capacity(n_dir);
        for _ in 0..n_dir {
            let c_raw: Vec<C64> = (0..h * cfg.p)
                .map(|_| C64::new(rng.normal(), rng.normal()).scale(scale_c))
                .collect();
            let mut ct = vec![C64::ZERO; h * p2];
            for r in 0..h {
                for c in 0..p2 {
                    let mut acc = C64::ZERO;
                    for k in 0..cfg.p {
                        acc += c_raw[r * cfg.p + k] * v[(k, c)];
                    }
                    ct[r * p2 + c] = acc;
                }
            }
            c_tilde.push(ct);
        }
        let log_dt: Vec<f32> = (0..p2)
            .map(|_| rng.uniform_in(cfg.dt_min.ln(), cfg.dt_max.ln()) as f32)
            .collect();
        S5Layer {
            lambda: lam,
            b_tilde,
            c_tilde,
            d: rng.normal_vec_f32(h),
            log_dt,
            gate_w: (0..h * h).map(|_| rng.normal() as f32 / (h as f64).sqrt() as f32).collect(),
            norm_scale: vec![1.0; h],
            norm_bias: vec![0.0; h],
            h,
            p2,
        }
    }

    /// Apply the SSM part (no norm/activation): u (L×H) → y (L×H).
    ///
    /// `threads` selects the scan backend (1 = sequential). `dts` enables
    /// the irregular-sampling path (§6.3).
    pub fn apply_ssm(
        &self,
        u: &[f32],
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        threads: usize,
    ) -> Vec<f32> {
        let (h, p2) = (self.h, self.p2);
        assert_eq!(u.len(), l * h);
        // bu_k = B̃ u_k (complex (L,P2))
        let mut bu = vec![C32::ZERO; l * p2];
        for k in 0..l {
            for r in 0..p2 {
                let mut acc = C64::ZERO;
                for c in 0..h {
                    acc += self.b_tilde[r * h + c].scale(u[k * h + c] as f64);
                }
                bu[k * p2 + r] = acc.to_c32();
            }
        }

        let xs = match dts {
            None => {
                let dt: Vec<f64> = self
                    .log_dt
                    .iter()
                    .map(|&ld| (ld as f64).exp() * timescale)
                    .collect();
                let (lam_bar, f) = discretize_diag(&self.lambda, &dt, Method::Zoh);
                let a32: Vec<C32> = lam_bar.iter().map(|z| z.to_c32()).collect();
                for k in 0..l {
                    for r in 0..p2 {
                        bu[k * p2 + r] = f[r].to_c32() * bu[k * p2 + r];
                    }
                }
                if threads <= 1 {
                    scan::scan_sequential_ti(&a32, &bu, l, p2)
                } else {
                    scan::scan_parallel_ti(&a32, &bu, l, p2, threads)
                }
            }
            Some(dts) => {
                assert_eq!(dts.len(), l);
                let base_dt: Vec<f64> = self
                    .log_dt
                    .iter()
                    .map(|&ld| (ld as f64).exp() * timescale)
                    .collect();
                let mut a_el = vec![C32::ZERO; l * p2];
                for k in 0..l {
                    for r in 0..p2 {
                        let dt = base_dt[r] * dts[k] as f64;
                        let (lb, f) =
                            crate::ssm::discretize::discretize_one(self.lambda[r], dt, Method::Zoh);
                        a_el[k * p2 + r] = lb.to_c32();
                        bu[k * p2 + r] = f.to_c32() * bu[k * p2 + r];
                    }
                }
                if threads <= 1 {
                    scan::scan_sequential(&a_el, &bu, l, p2)
                } else {
                    scan::scan_parallel_tv(&a_el, &bu, l, p2, threads)
                }
            }
        };

        // y = 2·Re(C̃ x) (+ backward direction) + D∘u
        let mut y = vec![0.0f32; l * h];
        self.project(&xs, l, 0, &mut y);
        if self.c_tilde.len() == 2 {
            // backward pass: scan the reversed drive, reverse back.
            // (time-invariant Λ̄ assumed for bidirectional models, as in L2)
            let dt: Vec<f64> = self
                .log_dt
                .iter()
                .map(|&ld| (ld as f64).exp() * timescale)
                .collect();
            let (lam_bar, f) = discretize_diag(&self.lambda, &dt, Method::Zoh);
            let a32: Vec<C32> = lam_bar.iter().map(|z| z.to_c32()).collect();
            // recompute drive reversed (bu was consumed in-place above only
            // by scaling with f — reuse requires a fresh B̃u)
            let mut bu_rev = vec![C32::ZERO; l * p2];
            for k in 0..l {
                let src = l - 1 - k;
                for r in 0..p2 {
                    let mut acc = C64::ZERO;
                    for c in 0..h {
                        acc += self.b_tilde[r * h + c].scale(u[src * h + c] as f64);
                    }
                    bu_rev[k * p2 + r] = (f[r] * acc).to_c32();
                }
            }
            let xs_b = if threads <= 1 {
                scan::scan_sequential_ti(&a32, &bu_rev, l, p2)
            } else {
                scan::scan_parallel_ti(&a32, &bu_rev, l, p2, threads)
            };
            // reverse the scan output back into natural time order
            let mut xs_rev = vec![C32::ZERO; l * p2];
            for k in 0..l {
                xs_rev[(l - 1 - k) * p2..(l - k) * p2]
                    .copy_from_slice(&xs_b[k * p2..(k + 1) * p2]);
            }
            self.project(&xs_rev, l, 1, &mut y);
        }
        for k in 0..l {
            for c in 0..h {
                y[k * h + c] += self.d[c] * u[k * h + c];
            }
        }
        y
    }

    /// Accumulate 2·Re(C̃_dir · x) into `y`.
    fn project(&self, xs: &[C32], l: usize, dir: usize, y: &mut [f32]) {
        let (h, p2) = (self.h, self.p2);
        let ct = &self.c_tilde[dir];
        for k in 0..l {
            for r in 0..h {
                let mut acc = 0.0f64;
                for c in 0..p2 {
                    let cv = ct[r * p2 + c];
                    let x = xs[k * p2 + c];
                    acc += cv.re * x.re as f64 - cv.im * x.im as f64;
                }
                y[k * h + r] += 2.0 * acc as f32;
            }
        }
    }

    /// Full layer: pre-norm → SSM → GELU → gate → residual.
    pub fn apply(
        &self,
        u: &[f32],
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        threads: usize,
    ) -> Vec<f32> {
        let h = self.h;
        let mut v = vec![0.0f32; l * h];
        for k in 0..l {
            layer_norm_row(
                &u[k * h..(k + 1) * h],
                &self.norm_scale,
                &self.norm_bias,
                &mut v[k * h..(k + 1) * h],
            );
        }
        let y = self.apply_ssm(&v, l, timescale, dts, threads);
        let mut out = vec![0.0f32; l * h];
        let mut g = vec![0.0f32; h];
        for k in 0..l {
            for c in 0..h {
                g[c] = gelu(y[k * h + c]);
            }
            for r in 0..h {
                let mut lin = 0.0f32;
                for c in 0..h {
                    lin += self.gate_w[r * h + c] * g[c];
                }
                out[k * h + r] = u[k * h + r] + g[r] * sigmoid(lin);
            }
        }
        out
    }

    /// Parameter count (matches the npz tensor sizes).
    pub fn param_count(&self) -> usize {
        2 * self.lambda.len()
            + 2 * self.b_tilde.len()
            + 2 * self.c_tilde.iter().map(|c| c.len()).sum::<usize>()
            + self.d.len()
            + self.log_dt.len()
            + self.gate_w.len()
            + self.norm_scale.len()
            + self.norm_bias.len()
    }
}

/// tanh-approximation GELU (matches `jax.nn.gelu` default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608f32; // sqrt(2/π)
    0.5 * x * (1.0 + ((C * (x + 0.044715 * x * x * x)).tanh()))
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// LayerNorm of one feature row.
pub fn layer_norm_row(x: &[f32], scale: &[f32], bias: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mu: f32 = x.iter().sum::<f32>() / n;
    let var: f32 = x.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-6).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mu) * inv * scale[i] + bias[i];
    }
}

/// A deep S5 model: encoder → layers → mean-pool → decoder (paper §G.1).
#[derive(Clone, Debug)]
pub struct S5Model {
    pub enc_w: Vec<f32>, // (H × d_in)
    pub enc_b: Vec<f32>,
    pub layers: Vec<S5Layer>,
    pub dec_w: Vec<f32>, // (classes × H)
    pub dec_b: Vec<f32>,
    pub d_in: usize,
    pub h: usize,
    pub classes: usize,
}

impl S5Model {
    pub fn init(
        d_in: usize,
        classes: usize,
        depth: usize,
        cfg: &S5Config,
        rng: &mut Rng,
    ) -> S5Model {
        let h = cfg.h;
        let se = 1.0 / (d_in as f64).sqrt();
        let sd = 1.0 / (h as f64).sqrt();
        S5Model {
            enc_w: (0..h * d_in).map(|_| (rng.normal() * se) as f32).collect(),
            enc_b: vec![0.0; h],
            layers: (0..depth).map(|_| S5Layer::init(cfg, rng)).collect(),
            dec_w: (0..classes * h).map(|_| (rng.normal() * sd) as f32).collect(),
            dec_b: vec![0.0; classes],
            d_in,
            h,
            classes,
        }
    }

    /// Logits for one sequence u (L × d_in).
    pub fn forward(&self, u: &[f32], l: usize, timescale: f64, threads: usize) -> Vec<f32> {
        let h = self.h;
        let mut x = vec![0.0f32; l * h];
        for k in 0..l {
            for r in 0..h {
                let mut acc = self.enc_b[r];
                for c in 0..self.d_in {
                    acc += self.enc_w[r * self.d_in + c] * u[k * self.d_in + c];
                }
                x[k * h + r] = acc;
            }
        }
        for layer in &self.layers {
            x = layer.apply(&x, l, timescale, None, threads);
        }
        // mean pool
        let mut pooled = vec![0.0f32; h];
        for k in 0..l {
            for r in 0..h {
                pooled[r] += x[k * h + r];
            }
        }
        for v in pooled.iter_mut() {
            *v /= l as f32;
        }
        let mut logits = vec![0.0f32; self.classes];
        for r in 0..self.classes {
            let mut acc = self.dec_b[r];
            for c in 0..h {
                acc += self.dec_w[r * h + c] * pooled[c];
            }
            logits[r] = acc;
        }
        logits
    }

    pub fn param_count(&self) -> usize {
        self.enc_w.len()
            + self.enc_b.len()
            + self.dec_w.len()
            + self.dec_b.len()
            + self.layers.iter().map(|l| l.param_count()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn layer(h: usize, p: usize, j: usize, bidir: bool) -> S5Layer {
        let cfg = S5Config { h, p, j, bidir, ..Default::default() };
        S5Layer::init(&cfg, &mut Rng::new(1))
    }

    #[test]
    fn layer_output_shape_and_finite() {
        let l = 64;
        let lp = layer(8, 8, 1, false);
        let mut rng = Rng::new(2);
        let u = rng.normal_vec_f32(l * 8);
        let y = lp.apply(&u, l, 1.0, None, 1);
        assert_eq!(y.len(), l * 8);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causal_without_bidir() {
        let l = 40;
        let lp = layer(6, 8, 1, false);
        let mut rng = Rng::new(3);
        let mut u = rng.normal_vec_f32(l * 6);
        let y1 = lp.apply(&u, l, 1.0, None, 1);
        u[(l - 1) * 6] += 5.0;
        let y2 = lp.apply(&u, l, 1.0, None, 1);
        for k in 0..(l - 1) * 6 {
            assert!((y1[k] - y2[k]).abs() < 1e-5, "leak at {k}");
        }
    }

    #[test]
    fn bidir_is_not_causal() {
        let l = 40;
        let lp = layer(6, 8, 1, true);
        let mut rng = Rng::new(4);
        let mut u = rng.normal_vec_f32(l * 6);
        let y1 = lp.apply(&u, l, 1.0, None, 1);
        u[(l - 1) * 6] += 5.0;
        let y2 = lp.apply(&u, l, 1.0, None, 1);
        let early_diff: f32 = (0..6).map(|c| (y1[c] - y2[c]).abs()).sum();
        assert!(early_diff > 1e-6);
    }

    #[test]
    fn prop_threads_agree() {
        prop::check("layer threads invariance", 10, |g| {
            let l = 16 + g.below(200);
            let lp = layer(4, 8, 1, false);
            let u: Vec<f32> = (0..l * 4).map(|_| g.normal() as f32).collect();
            let y1 = lp.apply(&u, l, 1.0, None, 1);
            let y4 = lp.apply(&u, l, 1.0, None, 4);
            prop::close_slice_f32(&y1, &y4, 1e-4)
        });
    }

    #[test]
    fn timescale_equals_dt_shift() {
        // ρ·Δ == exp(logΔ + ln ρ): zero-shot resampling identity (§6.2).
        let mut lp = layer(4, 8, 1, false);
        let l = 32;
        let mut rng = Rng::new(5);
        let u = rng.normal_vec_f32(l * 4);
        let y1 = lp.apply_ssm(&u, l, 2.0, None, 1);
        for ld in lp.log_dt.iter_mut() {
            *ld += (2.0f32).ln();
        }
        let y2 = lp.apply_ssm(&u, l, 1.0, None, 1);
        prop::close_slice_f32(&y1, &y2, 1e-4).unwrap();
    }

    #[test]
    fn variable_dt_unit_matches_fixed() {
        let lp = layer(4, 8, 2, false);
        let l = 25;
        let mut rng = Rng::new(6);
        let u = rng.normal_vec_f32(l * 4);
        let fixed = lp.apply_ssm(&u, l, 1.0, None, 1);
        let var = lp.apply_ssm(&u, l, 1.0, Some(&vec![1.0; l]), 1);
        prop::close_slice_f32(&fixed, &var, 1e-4).unwrap();
    }

    #[test]
    fn model_forward_shape() {
        let cfg = S5Config { h: 16, p: 16, j: 2, ..Default::default() };
        let m = S5Model::init(2, 10, 2, &cfg, &mut Rng::new(7));
        let mut rng = Rng::new(8);
        let u = rng.normal_vec_f32(50 * 2);
        let logits = m.forward(&u, 50, 1.0, 1);
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(m.param_count() > 1000);
    }

    #[test]
    fn gelu_matches_reference_points() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-3);
    }
}
