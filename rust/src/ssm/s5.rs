//! Pure-Rust S5 layer and deep model (the L3 parity oracle — and, since
//! the batched-engine refactor, the subject the native inference server
//! actually serves).
//!
//! This mirrors `python/compile/model.py` operation-for-operation so the
//! compiled HLO can be checked bitwise-loosely (f32 tolerances) against an
//! independent implementation — and so the runtime benchmarks (Table 4,
//! Prop. 1) have an S5 subject whose inner loops we control.
//!
//! The layer (paper §3, §G.1):
//!   pre-LayerNorm → ZOH-discretized MIMO SSM via scan → y = 2·Re(C̃x̃) + D∘u
//!   → GELU → weighted-sigmoid gate → residual.
//!
//! ## Batched forward path
//!
//! The hot entry points take packed row-major (B, L, H) batches, a
//! [`ScanBackend`] strategy object and an [`EngineWorkspace`] that owns all
//! large scratch ([`S5Model::forward_batch_into`], [`S5Layer::apply_batch`],
//! [`S5Layer::apply_ssm_batch`]). The SSM stage dispatches on the
//! backend's [`ScanLayout`] and the engine
//! [`ScanPolicy`](crate::ssm::engine::ScanPolicy): the default is the
//! **fused cache-blocked** planar pipeline — every (sequence, direction)
//! processes its L in tiles sized to the L2 budget, fusing drive → Δt
//! scale → tile-resumable scan → projection (+ feedthrough) per tile, so
//! the workspace's scan buffers hold O(B·T·P2) instead of full
//! (B, L, P2) planes and each tile stays cache-resident end-to-end.
//! [`Tiling::Staged`](crate::ssm::engine::Tiling::Staged) selects the
//! untiled full-plane planar pipeline (the pre-tiling behavior), and the
//! interleaved `[C32]` path is kept as the staged reference oracle. The
//! fused pipeline's in-tile scans are sequential (pipelines shard across
//! the worker pool instead), so fused ≡ staged-sequential ≡
//! interleaved-sequential **bit-for-bit** for any tile size, thread
//! budget and executor; staged planar ≡ staged interleaved bit-for-bit
//! at equal strategy. Per-sequence math is factored into `*_seq` helpers
//! shared by every path, so a batch of B is elementwise identical to B
//! independent forwards (up to the staged parallel strategy's documented
//! 1e-4 chunk-combine tolerance). The original single-sequence
//! signatures ([`S5Layer::apply`], [`S5Layer::apply_ssm`],
//! [`S5Model::forward`]) remain as deprecated batch-of-1 wrappers that
//! allocate a private workspace; the typed entry point is the
//! [`SequenceModel`] impl (see [`crate::ssm::api`]), which also provides
//! streaming via `make_state`/`step` and native checkpoint import via
//! [`S5Model::from_param_store`].

use crate::num::{C32, C64};
use crate::rng::Rng;
use crate::ssm::api::{Batch, ForwardOptions, ModelSpec, SequenceModel, SessionState};
use crate::ssm::discretize::{discretize_one, Method};
use crate::ssm::dtype::{Bf16, Dtype, ScanElem};
use crate::ssm::engine::{
    grow, par_zip, par_zip2, par_zip4, ti_disc, EngineWorkspace, ScanPolicy, SsmBuffers, TiDisc,
};
use crate::ssm::hippo;
use crate::ssm::online::S5StreamState;
use crate::ssm::scan::{
    ParallelBackend, PlanarElem, ScanBackend, ScanLayout, SequentialBackend,
};

/// Parameters of one S5 layer (conjugate-symmetric storage: P2 = P/2).
#[derive(Clone, Debug)]
pub struct S5Layer {
    /// Continuous-time eigenvalues Λ (length P2).
    pub lambda: Vec<C64>,
    /// Input matrix B̃ (P2 × H), row-major.
    pub b_tilde: Vec<C64>,
    /// Output matrices C̃ (n_dir × H × P2): 1 causal, 2 bidirectional.
    pub c_tilde: Vec<Vec<C64>>,
    /// Feedthrough D (H).
    pub d: Vec<f32>,
    /// log Δ (P2) — vector timescales (§4.3/D.5).
    pub log_dt: Vec<f32>,
    /// Weighted-sigmoid gate W (H × H).
    pub gate_w: Vec<f32>,
    /// LayerNorm scale/bias (H).
    pub norm_scale: Vec<f32>,
    pub norm_bias: Vec<f32>,
    pub h: usize,
    pub p2: usize,
}

/// Hyper-knobs for native initialization (mirrors `init_s5_layer`).
#[derive(Clone, Debug)]
pub struct S5Config {
    pub h: usize,
    pub p: usize,
    pub j: usize,
    pub conj_sym: bool,
    pub dt_min: f64,
    pub dt_max: f64,
    pub bidir: bool,
}

impl Default for S5Config {
    fn default() -> Self {
        S5Config { h: 32, p: 32, j: 1, conj_sym: true, dt_min: 1e-3, dt_max: 1e-1, bidir: false }
    }
}

/// One (sequence, direction) unit of the fused cache-blocked forward:
/// the disjoint borrows a tile pipeline works over. Units shard across
/// the backend's executor — each is an independent sequential pipeline,
/// so the fused result is executor- and thread-count-invariant by
/// construction.
///
/// `T` is the **storage** dtype of the tile drive planes
/// ([`ScanPolicy::dtype`](crate::ssm::engine::ScanPolicy)); every other
/// field — TV multipliers, carry states, outputs — stays f32/f64
/// compute precision regardless (the storage/compute split; see the
/// crate-level "Precision model" docs).
pub(crate) struct FusedUnit<'a, T: ScanElem = f32> {
    /// scan direction: 0 = forward, 1 = reversed (bidirectional backward)
    pub dir: usize,
    /// this sequence's (L, H) input rows (pre-normed activations)
    pub useq: &'a [f32],
    /// per-step Δt multipliers (L) in *scan-time* order: the caller's
    /// sequence for forward units, the reversed sequence for backward
    /// units (so row k always discretizes the Δt of the source step the
    /// tile drive read)
    pub dseq: Option<&'a [f32]>,
    /// output rows: y (dir 0) or the backward accumulator plane (dir 1)
    pub yseq: &'a mut [f32],
    /// tile drive planes (T, P2), in the policy's storage dtype
    pub dr: &'a mut [T],
    pub di: &'a mut [T],
    /// tile TV multiplier planes (T, P2) — irregular-Δt units (both
    /// directions)
    pub tv: Option<(&'a mut [f32], &'a mut [f32])>,
    /// carried f32 scan state (P2)
    pub sr: &'a mut [f32],
    pub si: &'a mut [f32],
    /// carried f64 scan state (P2) — [`ScanPolicy::f64_state`] only
    pub s64: Option<(&'a mut [f64], &'a mut [f64])>,
}

/// Backend preserving the legacy `threads: usize` knob of the
/// single-sequence entry points: ≤ 1 → sequential, else parallel.
fn legacy_backend(threads: usize) -> Box<dyn ScanBackend> {
    if threads <= 1 {
        Box::new(SequentialBackend)
    } else {
        Box::new(ParallelBackend::new(threads))
    }
}

impl S5Layer {
    /// HiPPO-N initialized layer (paper §3.2, B.1).
    pub fn init(cfg: &S5Config, rng: &mut Rng) -> S5Layer {
        let (lam, v, vinv) = hippo::block_diag_hippo_init(cfg.p, cfg.j, cfg.conj_sym);
        let p2 = lam.len();
        let h = cfg.h;
        // B sampled real (lecun normal) then rotated: B̃ = V⁻¹B.
        let mut b_tilde = vec![C64::ZERO; p2 * h];
        let scale_b = 1.0 / (h as f64).sqrt();
        let b_cols: Vec<f64> = (0..cfg.p * h).map(|_| rng.normal() * scale_b).collect();
        for r in 0..p2 {
            for c in 0..h {
                let mut acc = C64::ZERO;
                for k in 0..cfg.p {
                    acc += vinv[(r, k)].scale(b_cols[k * h + c]);
                }
                b_tilde[r * h + c] = acc;
            }
        }
        // C sampled complex then rotated: C̃ = C·V.
        let n_dir = if cfg.bidir { 2 } else { 1 };
        let scale_c = (0.5 / cfg.p as f64).sqrt();
        let mut c_tilde = Vec::with_capacity(n_dir);
        for _ in 0..n_dir {
            let c_raw: Vec<C64> = (0..h * cfg.p)
                .map(|_| C64::new(rng.normal(), rng.normal()).scale(scale_c))
                .collect();
            let mut ct = vec![C64::ZERO; h * p2];
            for r in 0..h {
                for c in 0..p2 {
                    let mut acc = C64::ZERO;
                    for k in 0..cfg.p {
                        acc += c_raw[r * cfg.p + k] * v[(k, c)];
                    }
                    ct[r * p2 + c] = acc;
                }
            }
            c_tilde.push(ct);
        }
        let log_dt: Vec<f32> = (0..p2)
            .map(|_| rng.uniform_in(cfg.dt_min.ln(), cfg.dt_max.ln()) as f32)
            .collect();
        S5Layer {
            lambda: lam,
            b_tilde,
            c_tilde,
            d: rng.normal_vec_f32(h),
            log_dt,
            gate_w: (0..h * h).map(|_| rng.normal() as f32 / (h as f64).sqrt() as f32).collect(),
            norm_scale: vec![1.0; h],
            norm_bias: vec![0.0; h],
            h,
            p2,
        }
    }

    // -- per-sequence kernels (shared by batched and single paths) ---------

    /// Drive of the scan: bu_k = B̃ u_k for one sequence (u: (L,H) →
    /// bu: (L,P2)); complex accumulation in f64, stored as C32.
    fn drive_seq(&self, u: &[f32], l: usize, bu: &mut [C32]) {
        let (h, p2) = (self.h, self.p2);
        for k in 0..l {
            for r in 0..p2 {
                let mut acc = C64::ZERO;
                for c in 0..h {
                    acc += self.b_tilde[r * h + c].scale(u[k * h + c] as f64);
                }
                bu[k * p2 + r] = acc.to_c32();
            }
        }
    }

    /// Reversed-time drive for the backward direction of a bidirectional
    /// layer, with the input scaling folded in (matches the original
    /// `(f[r] * acc).to_c32()` op order).
    /// Reversed-time drive for one sequence. `f` folds the time-invariant
    /// input scaling in at f64 before the C32 rounding (the TI backward
    /// pass); `None` leaves the drive raw for the per-row TV scaling of
    /// the irregular-Δt backward pass.
    fn drive_rev_seq(&self, u: &[f32], l: usize, f: Option<&[C64]>, bu_rev: &mut [C32]) {
        let (h, p2) = (self.h, self.p2);
        for k in 0..l {
            let src = l - 1 - k;
            for r in 0..p2 {
                let mut acc = C64::ZERO;
                for c in 0..h {
                    acc += self.b_tilde[r * h + c].scale(u[src * h + c] as f64);
                }
                if let Some(f) = f {
                    acc = f[r] * acc;
                }
                bu_rev[k * p2 + r] = acc.to_c32();
            }
        }
    }

    /// Scale one sequence's drive by the (time-invariant) input scaling f.
    fn scale_seq(bu: &mut [C32], f32s: &[C32], l: usize, p2: usize) {
        for k in 0..l {
            for r in 0..p2 {
                bu[k * p2 + r] = f32s[r] * bu[k * p2 + r];
            }
        }
    }

    /// Planar drive: bu_k = B̃ u_k for one sequence, written as separate
    /// re/im planes (same f64 accumulation and `to_c32` rounding as
    /// [`S5Layer::drive_seq`], so the two layouts agree bit-for-bit).
    /// Generic over the storage dtype: the accumulate → `to_c32` op order
    /// is unchanged, a narrow store (`T::from_f32`, RNE) is appended —
    /// the identity for f32.
    fn drive_seq_planar<T: ScanElem>(&self, u: &[f32], l: usize, bur: &mut [T], bui: &mut [T]) {
        let (h, p2) = (self.h, self.p2);
        for k in 0..l {
            for r in 0..p2 {
                let mut acc = C64::ZERO;
                for c in 0..h {
                    acc += self.b_tilde[r * h + c].scale(u[k * h + c] as f64);
                }
                let z = acc.to_c32();
                bur[k * p2 + r] = T::from_f32(z.re);
                bui[k * p2 + r] = T::from_f32(z.im);
            }
        }
    }

    // s5:hot-begin — per-tile drive/scale/project kernels, the norm/gate
    // stages and the fused tile pipeline: everything here runs per layer
    // per forward on the serving path and works strictly in caller-owned
    // scratch (lint L3; runtime twin in tests/alloc_guard.rs).

    /// Planar reversed-time drive with the input scaling folded in
    /// (mirrors [`S5Layer::drive_rev_seq`]); `f: None` leaves the drive
    /// raw for the TV backward pass.
    fn drive_rev_seq_planar<T: ScanElem>(
        &self,
        u: &[f32],
        l: usize,
        f: Option<&[C64]>,
        bur: &mut [T],
        bui: &mut [T],
    ) {
        // the whole sequence as one window of the tile form, so the
        // staged and fused backward drives share one implementation
        self.drive_rev_tile_planar(u, l, 0, l, f, bur, bui);
    }

    /// Planar drive scaling: `bu ← f ∘ bu` over separate planes, with the
    /// complex-multiply op order of [`S5Layer::scale_seq`]. Dispatches to
    /// the dtype's lane-blocked kernel under the `simd` feature
    /// (bit-identical to the scalar loop below at every dtype — see
    /// [`crate::ssm::simd`]); the scalar loop widens, multiplies in f32
    /// and narrow-stores (both identities for f32).
    fn scale_seq_planar<T: PlanarElem>(
        bur: &mut [T],
        bui: &mut [T],
        fr: &[f32],
        fi: &[f32],
        l: usize,
        p2: usize,
    ) {
        if cfg!(feature = "simd") {
            return T::scale_rows_simd(bur, bui, fr, fi, l, p2);
        }
        for k in 0..l {
            let row = k * p2;
            for r in 0..p2 {
                let br = bur[row + r].to_f32();
                let bi = bui[row + r].to_f32();
                bur[row + r] = T::from_f32(fr[r] * br - fi[r] * bi);
                bui[row + r] = T::from_f32(fr[r] * bi + fi[r] * br);
            }
        }
    }

    /// The planar time-varying discretize + scale pass over a row window:
    /// for each row k, per-state ZOH discretization at Δt =
    /// `base_dt[r] · dseq[k]`, writing the Λ̄ multiplier planes and
    /// scaling the drive planes in place. This is the **single** copy of
    /// the TV op sequence both the staged pass and the fused tile
    /// pipeline call, so the fused ≡ staged bit-for-bit contract cannot
    /// drift between them.
    #[allow(clippy::too_many_arguments)]
    fn tv_disc_scale_rows<T: ScanElem>(
        &self,
        base_dt: &[f64],
        dseq: &[f32],
        rows: usize,
        ar: &mut [f32],
        ai: &mut [f32],
        br: &mut [T],
        bi: &mut [T],
    ) {
        // the Λ̄ multiplier planes stay f32 compute precision at every
        // storage dtype (they seed the f32 recurrence); only the drive
        // store narrows
        let p2 = self.p2;
        for k in 0..rows {
            let dk = dseq[k] as f64;
            for r in 0..p2 {
                let dt = base_dt[r] * dk;
                let (lb, f) = discretize_one(self.lambda[r], dt, Method::Zoh);
                let lb = lb.to_c32();
                let f = f.to_c32();
                ar[k * p2 + r] = lb.re;
                ai[k * p2 + r] = lb.im;
                let (b_re, b_im) = (br[k * p2 + r].to_f32(), bi[k * p2 + r].to_f32());
                br[k * p2 + r] = T::from_f32(f.re * b_re - f.im * b_im);
                bi[k * p2 + r] = T::from_f32(f.re * b_im + f.im * b_re);
            }
        }
    }

    /// Planar reversed-time drive for one L-tile of the backward
    /// direction: reversed rows `t0..t0+tl` (reversed row k reads source
    /// row `l−1−k`), with the time-invariant input scaling folded in
    /// (`f: None` for the TV backward pass, whose per-row scaling runs in
    /// [`S5Layer::tv_disc_scale_rows`]) — the exact per-row ops of
    /// [`S5Layer::drive_rev_seq_planar`], windowed.
    #[allow(clippy::too_many_arguments)]
    fn drive_rev_tile_planar<T: ScanElem>(
        &self,
        u: &[f32],
        l: usize,
        t0: usize,
        tl: usize,
        f: Option<&[C64]>,
        bur: &mut [T],
        bui: &mut [T],
    ) {
        let (h, p2) = (self.h, self.p2);
        for k in 0..tl {
            let src = l - 1 - (t0 + k);
            for r in 0..p2 {
                let mut acc = C64::ZERO;
                for c in 0..h {
                    acc += self.b_tilde[r * h + c].scale(u[src * h + c] as f64);
                }
                if let Some(f) = f {
                    acc = f[r] * acc;
                }
                let z = acc.to_c32();
                bur[k * p2 + r] = T::from_f32(z.re);
                bui[k * p2 + r] = T::from_f32(z.im);
            }
        }
    }

    /// Planar projection: accumulate 2·Re(C̃_dir · x) into `y` from
    /// separate state planes (mirrors [`S5Layer::project_seq`]).
    /// Dispatches to the channel-blocked kernel under the `simd` feature
    /// (bit-identical — each channel keeps its own sequential f64
    /// reduction; see [`crate::ssm::simd`]).
    fn project_seq_planar<T: PlanarElem>(
        &self,
        xr: &[T],
        xi: &[T],
        l: usize,
        dir: usize,
        reversed: bool,
        y: &mut [f32],
    ) {
        let (h, p2) = (self.h, self.p2);
        let ct = &self.c_tilde[dir];
        for k in 0..l {
            let xrow = if reversed { (l - 1 - k) * p2 } else { k * p2 };
            if cfg!(feature = "simd") {
                T::project_row_simd(
                    ct,
                    &xr[xrow..xrow + p2],
                    &xi[xrow..xrow + p2],
                    &mut y[k * h..(k + 1) * h],
                    h,
                    p2,
                );
            } else {
                for r in 0..h {
                    let mut acc = 0.0f64;
                    for c in 0..p2 {
                        let cv = ct[r * p2 + c];
                        acc += cv.re * xr[xrow + c].to_f32() as f64
                            - cv.im * xi[xrow + c].to_f32() as f64;
                    }
                    y[k * h + r] += 2.0 * acc as f32;
                }
            }
        }
    }

    /// Accumulate 2·Re(C̃_dir · x) into `y` for one sequence. `reversed`
    /// reads the state rows back-to-front (backward direction of a
    /// bidirectional layer, whose scan ran on reversed time).
    fn project_seq(&self, xs: &[C32], l: usize, dir: usize, reversed: bool, y: &mut [f32]) {
        let (h, p2) = (self.h, self.p2);
        let ct = &self.c_tilde[dir];
        for k in 0..l {
            let xrow = if reversed { (l - 1 - k) * p2 } else { k * p2 };
            for r in 0..h {
                let mut acc = 0.0f64;
                for c in 0..p2 {
                    let cv = ct[r * p2 + c];
                    let x = xs[xrow + c];
                    acc += cv.re * x.re as f64 - cv.im * x.im as f64;
                }
                y[k * h + r] += 2.0 * acc as f32;
            }
        }
    }

    /// y += D ∘ u for one sequence.
    fn feedthrough_seq(&self, u: &[f32], l: usize, y: &mut [f32]) {
        let h = self.h;
        for k in 0..l {
            for c in 0..h {
                y[k * h + c] += self.d[c] * u[k * h + c];
            }
        }
    }

    /// Pre-norm of one sequence: v_k = LayerNorm(u_k).
    pub(crate) fn norm_seq(&self, u: &[f32], l: usize, v: &mut [f32]) {
        let h = self.h;
        for k in 0..l {
            layer_norm_row(
                &u[k * h..(k + 1) * h],
                &self.norm_scale,
                &self.norm_bias,
                &mut v[k * h..(k + 1) * h],
            );
        }
    }

    /// GELU → weighted-sigmoid gate → residual, in place over the layer
    /// input `x` (reads SSM output `y`): x_k ← x_k + g ∘ σ(W g).
    ///
    /// `g` is caller-owned scratch for one GELU row (≥ `h` elements, any
    /// contents) — this runs per layer per forward on the serving path
    /// and must not allocate (lint L3 / the alloc_guard tests); callers
    /// lend a dead workspace row.
    pub(crate) fn gate_residual_seq(&self, y: &[f32], x: &mut [f32], l: usize, g: &mut [f32]) {
        let h = self.h;
        let g = &mut g[..h];
        for k in 0..l {
            for c in 0..h {
                g[c] = gelu(y[k * h + c]);
            }
            for r in 0..h {
                let mut lin = 0.0f32;
                for c in 0..h {
                    lin += self.gate_w[r * h + c] * g[c];
                }
                x[k * h + r] += g[r] * sigmoid(lin);
            }
        }
    }

    // -- fused cache-blocked pipeline --------------------------------------

    /// Run one (sequence, direction) tile pipeline of the fused
    /// cache-blocked forward: for each L-tile, drive → (Δt) scale →
    /// tile-resumable scan → projection (with the feedthrough folded in
    /// for unidirectional layers), carrying the scan state across tile
    /// boundaries. The working set per tile is O(T·P2) — the whole point
    /// of the blocking — and every per-element FP op matches the staged
    /// pipeline's op order exactly.
    ///
    /// `resume == false` (offline forwards): the first tile runs the
    /// plain sequential kernel (row 0 = b_0, the staged op order), later
    /// tiles resume from the copied-out carry — fused ≡ staged-sequential
    /// bit-for-bit. `resume == true` (chunked streaming prefill): every
    /// tile resumes from the live carry in `sr`/`si`, whose per-row op is
    /// exactly [`ScanBackend::scan_step_planar`] — fused ≡ step replay
    /// bit-for-bit, and the stream state is updated in place.
    ///
    /// With an f64 carry (`s64`) every tile resumes through the f64
    /// kernels; the result is tile-decomposition invariant because the
    /// carry never round-trips through f32.
    ///
    /// `wide` is the in-tile worker budget ([`ScanPolicy::wide`], granted
    /// per unit by [`S5Layer::apply_ssm_fused`]; pass 1 for the exact
    /// sequential behavior). With `wide > 1` the drive/Δt-scale and
    /// projection row-split across the backend's executor (row-
    /// independent, so bit-exact) and the tile scan runs the seeded
    /// chunked-parallel resume kernels with `pscratch` as their
    /// caller-pooled chunk-summary buffer (tolerance-pinned — see the
    /// policy docs). The f64-state path ignores `wide` (its
    /// tile-invariance contract needs a continuous sequential carry).
    ///
    /// Generic over the drive-plane **storage** dtype `T`
    /// ([`PlanarElem`]): every scan routes through the dtype's kernels,
    /// which widen on load, run the recurrence in f32 and narrow-store —
    /// all identities for f32, so the f32 instantiation compiles to the
    /// pre-dtype code. The carry (`sr`/`si`/`s64`) stays full precision
    /// across tiles at every dtype; under bf16 the "first" tile runs the
    /// resume kernel from the pre-zeroed carry (see
    /// [`PlanarElem::scan_ti_first`]).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn fused_unit<T: PlanarElem>(
        &self,
        unit: &mut FusedUnit<'_, T>,
        l: usize,
        tile: usize,
        a_re: &[f32],
        a_im: &[f32],
        f_re: &[f32],
        f_im: &[f32],
        f_rev: &[C64],
        base_dt: &[f64],
        backend: &dyn ScanBackend,
        resume: bool,
        fold_feedthrough: bool,
        wide: usize,
        pscratch: &mut Vec<f32>,
    ) {
        let (h, p2) = (self.h, self.p2);
        let tcap = tile.min(l).max(1);
        let mut first = !resume;
        let mut t0 = 0usize;
        while t0 < l {
            let tl = tcap.min(l - t0);
            let np = tl * p2;
            // in-tile split: `parts` row-chunks of `rows_per` rows each
            let parts = if h == 0 || p2 == 0 { 1 } else { wide.max(1).min(tl) };
            let rows_per = tl.div_ceil(parts);
            // drive (+ scale / TV discretize) for this tile's rows
            if unit.dir == 0 {
                let dr = &mut unit.dr[..np];
                let di = &mut unit.di[..np];
                if parts > 1 {
                    // rows are independent: chunked drive+scale is
                    // bit-exact vs the single-pass form
                    let ex = backend.executor();
                    let u_t = &unit.useq[t0 * h..(t0 + tl) * h];
                    match (&mut unit.tv, unit.dseq) {
                        (Some((atr, ati)), Some(dseq)) => {
                            let dseq_t = &dseq[t0..t0 + tl];
                            ex.run_tasks(
                                dr.chunks_mut(rows_per * p2)
                                    .zip(di.chunks_mut(rows_per * p2))
                                    .zip(atr[..np].chunks_mut(rows_per * p2))
                                    .zip(ati[..np].chunks_mut(rows_per * p2))
                                    .zip(u_t.chunks(rows_per * h))
                                    .zip(dseq_t.chunks(rows_per))
                                    .map(|(((((dcr, dci), acr), aci), uc), dc)| {
                                        move || {
                                            let rows = dc.len();
                                            self.drive_seq_planar(uc, rows, dcr, dci);
                                            self.tv_disc_scale_rows(
                                                base_dt, dc, rows, acr, aci, dcr, dci,
                                            );
                                        }
                                    }),
                            );
                        }
                        _ => {
                            ex.run_tasks(
                                dr.chunks_mut(rows_per * p2)
                                    .zip(di.chunks_mut(rows_per * p2))
                                    .zip(u_t.chunks(rows_per * h))
                                    .map(|((dcr, dci), uc)| {
                                        move || {
                                            let rows = uc.len() / h;
                                            self.drive_seq_planar(uc, rows, dcr, dci);
                                            Self::scale_seq_planar(
                                                dcr, dci, f_re, f_im, rows, p2,
                                            );
                                        }
                                    }),
                            );
                        }
                    }
                } else {
                    self.drive_seq_planar(&unit.useq[t0 * h..(t0 + tl) * h], tl, dr, di);
                    match (&mut unit.tv, unit.dseq) {
                        (Some((atr, ati)), Some(dseq)) => {
                            // irregular sampling: per-step ZOH discretization
                            // through the shared TV row pass (same ops as the
                            // staged pipeline by construction)
                            self.tv_disc_scale_rows(
                                base_dt,
                                &dseq[t0..t0 + tl],
                                tl,
                                &mut atr[..np],
                                &mut ati[..np],
                                dr,
                                di,
                            );
                        }
                        _ => Self::scale_seq_planar(dr, di, f_re, f_im, tl, p2),
                    }
                }
            } else {
                // backward direction: reversed drive. A TV backward unit
                // carries the *reversed* Δt sequence in `dseq`, so row k
                // pairs Λ̄, f and B̃u all from source row l−1−(t0+k) —
                // the same per-row TV pass as the forward direction, just
                // over a raw (unscaled) reversed drive.
                let dr = &mut unit.dr[..np];
                let di = &mut unit.di[..np];
                let useq = unit.useq;
                if parts > 1 {
                    let ex = backend.executor();
                    match (&mut unit.tv, unit.dseq) {
                        (Some((atr, ati)), Some(dseq)) => {
                            let dseq_t = &dseq[t0..t0 + tl];
                            ex.run_tasks(
                                dr.chunks_mut(rows_per * p2)
                                    .zip(di.chunks_mut(rows_per * p2))
                                    .zip(atr[..np].chunks_mut(rows_per * p2))
                                    .zip(ati[..np].chunks_mut(rows_per * p2))
                                    .zip(dseq_t.chunks(rows_per))
                                    .enumerate()
                                    .map(|(ci, ((((dcr, dci), acr), aci), dc))| {
                                        move || {
                                            let rows = dc.len();
                                            self.drive_rev_tile_planar(
                                                useq,
                                                l,
                                                t0 + ci * rows_per,
                                                rows,
                                                None,
                                                dcr,
                                                dci,
                                            );
                                            self.tv_disc_scale_rows(
                                                base_dt, dc, rows, acr, aci, dcr, dci,
                                            );
                                        }
                                    }),
                            );
                        }
                        _ => {
                            ex.run_tasks(
                                dr.chunks_mut(rows_per * p2)
                                    .zip(di.chunks_mut(rows_per * p2))
                                    .enumerate()
                                    .map(|(ci, (dcr, dci))| {
                                        move || {
                                            let rows = dcr.len() / p2;
                                            self.drive_rev_tile_planar(
                                                useq,
                                                l,
                                                t0 + ci * rows_per,
                                                rows,
                                                Some(f_rev),
                                                dcr,
                                                dci,
                                            );
                                        }
                                    }),
                            );
                        }
                    }
                } else {
                    match (&mut unit.tv, unit.dseq) {
                        (Some((atr, ati)), Some(dseq)) => {
                            self.drive_rev_tile_planar(useq, l, t0, tl, None, dr, di);
                            self.tv_disc_scale_rows(
                                base_dt,
                                &dseq[t0..t0 + tl],
                                tl,
                                &mut atr[..np],
                                &mut ati[..np],
                                dr,
                                di,
                            );
                        }
                        _ => {
                            self.drive_rev_tile_planar(useq, l, t0, tl, Some(f_rev), dr, di);
                        }
                    }
                }
            }
            // scan: sequential within the tile by default, carrying state
            // across tile boundaries (parallelism lives one level up,
            // across the sequence × direction pipelines); with a wide
            // budget the tile scan itself runs chunked-parallel, seeded
            // from the carry (the caller pre-zeroes it, so the first tile
            // needs no special case)
            {
                let dr = &mut unit.dr[..np];
                let di = &mut unit.di[..np];
                if let Some((s64r, s64i)) = unit.s64.as_mut() {
                    match unit.tv.as_ref() {
                        Some((atr, ati)) => T::scan_tv_f64(
                            &atr[..np],
                            &ati[..np],
                            s64r,
                            s64i,
                            dr,
                            di,
                            tl,
                            p2,
                        ),
                        None => T::scan_ti_f64(a_re, a_im, s64r, s64i, dr, di, tl, p2),
                    }
                } else if parts > 1 {
                    match unit.tv.as_ref() {
                        Some((atr, ati)) => T::scan_tv_resume_par(
                            backend,
                            &atr[..np],
                            &ati[..np],
                            unit.sr,
                            unit.si,
                            dr,
                            di,
                            tl,
                            p2,
                            parts,
                            pscratch,
                        ),
                        None => T::scan_ti_resume_par(
                            backend, a_re, a_im, unit.sr, unit.si, dr, di, tl, p2, parts, pscratch,
                        ),
                    }
                } else if first {
                    // the dtype owns its first-tile strategy: f32 runs the
                    // zero-scratch sequential kernel and copies the carry
                    // out, bf16 resumes from the pre-zeroed carry
                    match unit.tv.as_ref() {
                        Some((atr, ati)) => T::scan_tv_first(
                            &atr[..np],
                            &ati[..np],
                            unit.sr,
                            unit.si,
                            dr,
                            di,
                            tl,
                            p2,
                        ),
                        None => T::scan_ti_first(a_re, a_im, unit.sr, unit.si, dr, di, tl, p2),
                    }
                } else {
                    match unit.tv.as_ref() {
                        Some((atr, ati)) => T::scan_tv_resume(
                            backend,
                            &atr[..np],
                            &ati[..np],
                            unit.sr,
                            unit.si,
                            dr,
                            di,
                            tl,
                            p2,
                        ),
                        None => T::scan_ti_resume(
                            backend, a_re, a_im, unit.sr, unit.si, dr, di, tl, p2,
                        ),
                    }
                }
            }
            // projection (+ feedthrough fold-in), straight off the warm
            // tile states
            {
                let xr = &unit.dr[..np];
                let xi = &unit.di[..np];
                if unit.dir == 0 {
                    let yw = &mut unit.yseq[t0 * h..(t0 + tl) * h];
                    if parts > 1 {
                        // output rows are independent: chunked projection
                        // (+ feedthrough) is bit-exact
                        let ex = backend.executor();
                        let u_t = &unit.useq[t0 * h..(t0 + tl) * h];
                        ex.run_tasks(
                            yw.chunks_mut(rows_per * h)
                                .zip(xr.chunks(rows_per * p2))
                                .zip(xi.chunks(rows_per * p2))
                                .zip(u_t.chunks(rows_per * h))
                                .map(|(((yc, xrc), xic), uc)| {
                                    move || {
                                        let rows = yc.len() / h;
                                        yc.fill(0.0);
                                        self.project_seq_planar(xrc, xic, rows, 0, false, yc);
                                        if fold_feedthrough {
                                            self.feedthrough_seq(uc, rows, yc);
                                        }
                                    }
                                }),
                        );
                    } else {
                        yw.fill(0.0);
                        self.project_seq_planar(xr, xi, tl, 0, false, yw);
                        if fold_feedthrough {
                            self.feedthrough_seq(&unit.useq[t0 * h..(t0 + tl) * h], tl, yw);
                        }
                    }
                } else {
                    // reversed tile: state row k is original row l−1−(t0+k)
                    let o0 = l - t0 - tl;
                    let yw = &mut unit.yseq[o0 * h..(o0 + tl) * h];
                    if parts > 1 {
                        // state chunk [c0, c0+rows) maps to y rows
                        // [o0+tl−c0−rows, o0+tl−c0): the y windows walk
                        // backwards as the state chunks walk forwards, so
                        // zip the state chunks against reverse y chunks
                        let ex = backend.executor();
                        ex.run_tasks(
                            yw.rchunks_mut(rows_per * h)
                                .zip(xr.chunks(rows_per * p2))
                                .zip(xi.chunks(rows_per * p2))
                                .map(|((yc, xrc), xic)| {
                                    move || {
                                        let rows = yc.len() / h;
                                        yc.fill(0.0);
                                        self.project_seq_planar(xrc, xic, rows, 1, true, yc);
                                    }
                                }),
                        );
                    } else {
                        yw.fill(0.0);
                        self.project_seq_planar(xr, xi, tl, 1, true, yw);
                    }
                }
            }
            first = false;
            t0 += tl;
        }
    }

    // s5:hot-end — apply_ssm_fused below owns the one sanctioned
    // multi-shard unit-list allocation (O(shards) boxed dispatch).

    /// The cache-blocked fused SSM path (planar layout, the default):
    /// every (sequence, direction) runs as an independent pipeline of
    /// L-tiles via [`S5Layer::fused_unit`], so `SsmBuffers` holds
    /// O(B·T·P2) instead of materializing full (B, L, P2) drive planes,
    /// and each tile's drive/state working set stays cache-resident from
    /// drive through projection. Pipelines shard across the backend's
    /// executor (the PR-4 worker pool); in-tile scans are sequential by
    /// default, so the result equals the staged pipeline over the
    /// sequential scan strategy **bit-for-bit** — independent of tile
    /// size, thread budget and executor (pinned by
    /// `tests/scan_matrix.rs`).
    ///
    /// With `wide` ([`ScanPolicy::wide`]) and fewer pipelines than
    /// threads, the leftover workers go *inside* each tile: the
    /// per-pipeline worker budget is `threads / n_units`, the tile is
    /// widened by the same factor (one cache budget per chunk worker,
    /// so per-worker locality matches the sequential tiling), and
    /// [`S5Layer::fused_unit`] row-splits drive/projection (bit-exact)
    /// and runs the seeded chunked-parallel tile scan
    /// (tolerance-pinned). The f64-state path keeps `wide` off — its
    /// carry contract is sequential.
    #[allow(clippy::too_many_arguments)]
    fn apply_ssm_fused<T: PlanarElem>(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        backend: &dyn ScanBackend,
        tile: usize,
        f64_state: bool,
        wide: bool,
        slot: usize,
        disc: &mut Vec<Vec<TiDisc>>,
        ssm: &mut SsmBuffers,
        y2: &mut Vec<f32>,
        y: &mut [f32],
    ) {
        let (h, p2) = (self.h, self.p2);
        let sh = l * h;
        let bidir = self.c_tilde.len() == 2;
        let n_units = batch * self.c_tilde.len();
        let t = backend.threads();
        let ex = backend.executor();
        // in-tile worker budget: only when pipelines alone can't fill the
        // thread budget (single-stream / low-batch regime)
        let inner = if wide && !f64_state && n_units > 0 && n_units < t {
            (t / n_units).max(1)
        } else {
            1
        };
        let tcap = tile.min(l).max(1);
        // widen the tile so each chunk worker gets one cache budget
        let tcap = if inner > 1 { tcap.saturating_mul(inner).min(l.max(1)) } else { tcap };
        let tcp2 = tcap * p2;
        if let Some(dts) = dts {
            assert_eq!(dts.len(), batch * l);
        }
        if p2 == 0 {
            // stateless degenerate layer: the SSM contributes nothing
            for (b, yseq) in y[..batch * sh].chunks_mut(sh).enumerate() {
                yseq.fill(0.0);
                self.feedthrough_seq(&u[b * sh..(b + 1) * sh], l, yseq);
            }
            return;
        }
        let d = ti_disc(disc, slot, &self.lambda, &self.log_dt, timescale);
        let SsmBuffers {
            bu_re,
            bu_im,
            bu_re16,
            bu_im16,
            a_tv_re,
            a_tv_im,
            dts_rev,
            state_re,
            state_im,
            state64_re,
            state64_im,
            scan,
            ..
        } = ssm;
        // the workspace carries both drive-plane families; the storage
        // dtype selects (and grows) exactly one of them
        let (bu_re, bu_im) = T::pick_drive((bu_re, bu_im), (bu_re16, bu_im16));
        grow(bu_re, n_units * tcp2);
        grow(bu_im, n_units * tcp2);
        grow(state_re, n_units * p2);
        grow(state_im, n_units * p2);
        state_re[..n_units * p2].fill(0.0);
        state_im[..n_units * p2].fill(0.0);
        if f64_state {
            grow(state64_re, n_units * p2);
            grow(state64_im, n_units * p2);
            state64_re[..n_units * p2].fill(0.0);
            state64_im[..n_units * p2].fill(0.0);
        }
        if dts.is_some() {
            // every unit needs multiplier planes under TV — the backward
            // direction discretizes per-row too (over reversed Δt)
            grow(a_tv_re, n_units * tcp2);
            grow(a_tv_im, n_units * tcp2);
        }
        if bidir {
            grow(y2, batch * sh);
        }
        // Backward TV units consume the Δt sequence in reversed order so
        // tile row k (scan time) discretizes source row l−1−k — pairing
        // Λ̄, f and B̃u from the same source step (the L2 reference
        // semantics; fixture-pinned by tests/parity_fixtures.rs).
        let dts_rev: Option<&[f32]> = match (bidir, dts) {
            (true, Some(dv)) => {
                grow(dts_rev, batch * l);
                for b in 0..batch {
                    for k in 0..l {
                        dts_rev[b * l + k] = dv[b * l + (l - 1 - k)];
                    }
                }
                Some(&dts_rev[..batch * l])
            }
            _ => None,
        };

        // Shard the pipelines across the executor. The decomposition is
        // fixed by the thread budget (never the executor), and each unit
        // runs its tiles in order, so results are invariant to both (with
        // an in-tile budget the chunking inside each tile is likewise
        // fixed by `inner`, never by the executor). Each shard carries a
        // pooled scratch Vec for the chunked scan's summary rows (unused,
        // and untouched, when `inner == 1`).
        let shards = t.max(1).min(n_units);
        let fold = !bidir;
        if inner > 1 {
            // pre-size so the steady state never allocates: shard i's Vec
            // is sized for t/(i+1) chunks ≥ the `inner` chunks it needs
            scan.reserve_planar(p2, t);
        }
        // The unit planes: disjoint borrows of tile buffers, carry states
        // and output rows. Forward units write y; backward units write the
        // y2 accumulator plane, summed (then feedthrough'd) in the combine
        // pass below — the staged op order.
        let mut dr_it = bu_re[..n_units * tcp2].chunks_mut(tcp2);
        let mut di_it = bu_im[..n_units * tcp2].chunks_mut(tcp2);
        let mut sr_it = state_re[..n_units * p2].chunks_mut(p2);
        let mut si_it = state_im[..n_units * p2].chunks_mut(p2);
        let mut s64r_it =
            if f64_state { Some(state64_re[..n_units * p2].chunks_mut(p2)) } else { None };
        let mut s64i_it =
            if f64_state { Some(state64_im[..n_units * p2].chunks_mut(p2)) } else { None };
        let mut tvr_it =
            if dts.is_some() { Some(a_tv_re[..n_units * tcp2].chunks_mut(tcp2)) } else { None };
        let mut tvi_it =
            if dts.is_some() { Some(a_tv_im[..n_units * tcp2].chunks_mut(tcp2)) } else { None };
        if shards <= 1 {
            // Single-shard regime: the sequential default, and the B = 1
            // unidirectional serving shape on any backend. Run each unit
            // as it is assembled — no unit list, no boxed dispatch; after
            // warmup this path allocates nothing (tests/alloc_guard.rs
            // pins it). Unit order, tile order and scratch handoff are
            // identical to the sharded path below.
            let w = &mut scan.f_workers(1)[0];
            for (b, yseq) in y[..batch * sh].chunks_mut(sh).enumerate() {
                let mut unit = FusedUnit {
                    dir: 0,
                    useq: &u[b * sh..(b + 1) * sh],
                    dseq: dts.map(|dv| &dv[b * l..(b + 1) * l]),
                    yseq,
                    dr: dr_it.next().unwrap(),
                    di: di_it.next().unwrap(),
                    tv: match (&mut tvr_it, &mut tvi_it) {
                        (Some(r), Some(i)) => Some((r.next().unwrap(), i.next().unwrap())),
                        _ => None,
                    },
                    sr: sr_it.next().unwrap(),
                    si: si_it.next().unwrap(),
                    s64: match (&mut s64r_it, &mut s64i_it) {
                        (Some(r), Some(i)) => Some((r.next().unwrap(), i.next().unwrap())),
                        _ => None,
                    },
                };
                self.fused_unit(
                    &mut unit, l, tcap, &d.a_re, &d.a_im, &d.f_re, &d.f_im, &d.f64s, &d.base_dt,
                    backend, false, fold, inner, w,
                );
            }
            if bidir {
                for (b, yseq) in y2[..batch * sh].chunks_mut(sh).enumerate() {
                    let mut unit = FusedUnit {
                        dir: 1,
                        useq: &u[b * sh..(b + 1) * sh],
                        dseq: dts_rev.map(|dv| &dv[b * l..(b + 1) * l]),
                        yseq,
                        dr: dr_it.next().unwrap(),
                        di: di_it.next().unwrap(),
                        tv: match (&mut tvr_it, &mut tvi_it) {
                            (Some(r), Some(i)) => Some((r.next().unwrap(), i.next().unwrap())),
                            _ => None,
                        },
                        sr: sr_it.next().unwrap(),
                        si: si_it.next().unwrap(),
                        s64: match (&mut s64r_it, &mut s64i_it) {
                            (Some(r), Some(i)) => Some((r.next().unwrap(), i.next().unwrap())),
                            _ => None,
                        },
                    };
                    self.fused_unit(
                        &mut unit, l, tcap, &d.a_re, &d.a_im, &d.f_re, &d.f_im, &d.f64s,
                        &d.base_dt, backend, false, fold, inner, w,
                    );
                }
            }
        } else {
            let mut units: Vec<FusedUnit<'_, T>> = Vec::with_capacity(n_units);
            for (b, yseq) in y[..batch * sh].chunks_mut(sh).enumerate() {
                units.push(FusedUnit {
                    dir: 0,
                    useq: &u[b * sh..(b + 1) * sh],
                    dseq: dts.map(|dv| &dv[b * l..(b + 1) * l]),
                    yseq,
                    dr: dr_it.next().unwrap(),
                    di: di_it.next().unwrap(),
                    tv: match (&mut tvr_it, &mut tvi_it) {
                        (Some(r), Some(i)) => Some((r.next().unwrap(), i.next().unwrap())),
                        _ => None,
                    },
                    sr: sr_it.next().unwrap(),
                    si: si_it.next().unwrap(),
                    s64: match (&mut s64r_it, &mut s64i_it) {
                        (Some(r), Some(i)) => Some((r.next().unwrap(), i.next().unwrap())),
                        _ => None,
                    },
                });
            }
            if bidir {
                for (b, yseq) in y2[..batch * sh].chunks_mut(sh).enumerate() {
                    units.push(FusedUnit {
                        dir: 1,
                        useq: &u[b * sh..(b + 1) * sh],
                        dseq: dts_rev.map(|dv| &dv[b * l..(b + 1) * l]),
                        yseq,
                        dr: dr_it.next().unwrap(),
                        di: di_it.next().unwrap(),
                        tv: match (&mut tvr_it, &mut tvi_it) {
                            (Some(r), Some(i)) => Some((r.next().unwrap(), i.next().unwrap())),
                            _ => None,
                        },
                        sr: sr_it.next().unwrap(),
                        si: si_it.next().unwrap(),
                        s64: match (&mut s64r_it, &mut s64i_it) {
                            (Some(r), Some(i)) => Some((r.next().unwrap(), i.next().unwrap())),
                            _ => None,
                        },
                    });
                }
            }
            let per = n_units.div_ceil(shards);
            let workers = scan.f_workers(shards);
            ex.run_tasks(units.chunks_mut(per).zip(workers.iter_mut()).map(|(chunk, w)| {
                move || {
                    for unit in chunk.iter_mut() {
                        self.fused_unit(
                            unit, l, tcap, &d.a_re, &d.a_im, &d.f_re, &d.f_im, &d.f64s,
                            &d.base_dt, backend, false, fold, inner, w,
                        );
                    }
                }
            }));
        }

        if bidir {
            // combine: y += backward projection, then the feedthrough —
            // per element the exact add order of the staged backward pass
            let y2r = &y2[..batch * sh];
            par_zip(ex, t, y2r, sh, y, sh, batch, |i, y2seq, yseq| {
                for (a, b) in yseq.iter_mut().zip(y2seq.iter()) {
                    *a += *b;
                }
                self.feedthrough_seq(&u[i * sh..(i + 1) * sh], l, yseq);
            });
        }
    }

    // -- batched core ------------------------------------------------------

    /// SSM over a packed (B, L, H) batch, writing y (B, L, H). Scan
    /// scratch comes from the workspace's [`SsmBuffers`]; `y` must be
    /// exactly B·L·H long. `dts` is (B, L) per-step Δt multipliers.
    /// `slot`/`disc` address this layer's cached TI discretization in the
    /// workspace (validated by value, so slot collisions only cost a
    /// recompute).
    ///
    /// Dispatches on [`ScanBackend::layout`] and the [`ScanPolicy`]: the
    /// planar layout (default) runs the **fused cache-blocked** tile
    /// pipeline ([`S5Layer::apply_ssm_fused`]) unless the policy pins
    /// [`Tiling::Staged`](crate::ssm::engine::Tiling::Staged), in which
    /// case it runs the untiled full-plane planar pipeline; the
    /// interleaved path is the retained staged reference oracle (always
    /// untiled, f32-only). The fused path with any tile/thread/executor
    /// equals the staged planar pipeline over the sequential scan
    /// strategy bit-for-bit; planar staged ≡ interleaved staged
    /// bit-for-bit at equal strategy.
    ///
    /// The policy's storage dtype instantiates the fused pipeline: f32
    /// (the default) is the pre-dtype code path bit-for-bit; bf16 stores
    /// the drive planes narrow and keeps every accumulation in f32 (see
    /// the crate-level "Precision model" docs). The f64-state option and
    /// the interleaved oracle layout always run f32 storage.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn apply_ssm_core(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        backend: &dyn ScanBackend,
        policy: ScanPolicy,
        slot: usize,
        disc: &mut Vec<Vec<TiDisc>>,
        ssm: &mut SsmBuffers,
        y2: &mut Vec<f32>,
        y: &mut [f32],
    ) {
        let h = self.h;
        assert_eq!(u.len(), batch * l * h);
        assert_eq!(y.len(), batch * l * h);
        if batch == 0 || l == 0 {
            return; // degenerate batch: nothing to write
        }
        match backend.layout() {
            ScanLayout::Planar => {
                let tile = policy.tiling.resolve(self.p2, h, dts.is_some());
                // the f64 carry lives in the fused pipeline; under the
                // staged policy the whole sequence runs as one tile
                let tile = if policy.f64_state { Some(tile.unwrap_or(l)) } else { tile };
                // storage dtype: f64-state forces f32 planes (its
                // tile-invariance contract is the precision story), and
                // bf16 storage only exists in the fused pipeline — a
                // staged policy runs as one fused tile rather than
                // through the f32-only full-plane path
                let dtype = if policy.f64_state { Dtype::F32 } else { policy.storage_dtype() };
                let tile = if dtype == Dtype::Bf16 { Some(tile.unwrap_or(l)) } else { tile };
                match (tile, dtype) {
                    (Some(tile), Dtype::F32) => self.apply_ssm_fused::<f32>(
                        u,
                        batch,
                        l,
                        timescale,
                        dts,
                        backend,
                        tile,
                        policy.f64_state,
                        policy.wide,
                        slot,
                        disc,
                        ssm,
                        y2,
                        y,
                    ),
                    (Some(tile), Dtype::Bf16) => self.apply_ssm_fused::<Bf16>(
                        u,
                        batch,
                        l,
                        timescale,
                        dts,
                        backend,
                        tile,
                        policy.f64_state,
                        policy.wide,
                        slot,
                        disc,
                        ssm,
                        y2,
                        y,
                    ),
                    (None, _) => self.apply_ssm_planar(
                        u, batch, l, timescale, dts, backend, slot, disc, ssm, y,
                    ),
                }
            }
            ScanLayout::Interleaved => {
                assert!(
                    !policy.f64_state,
                    "f64 scan state requires the planar layout (the interleaved oracle is f32-only)"
                );
                self.apply_ssm_interleaved(u, batch, l, timescale, dts, backend, slot, disc, ssm, y)
            }
        }
    }

    /// The planar (struct-of-arrays) SSM path — the engine default.
    #[allow(clippy::too_many_arguments)]
    fn apply_ssm_planar(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        backend: &dyn ScanBackend,
        slot: usize,
        disc: &mut Vec<Vec<TiDisc>>,
        ssm: &mut SsmBuffers,
        y: &mut [f32],
    ) {
        let (h, p2) = (self.h, self.p2);
        let np = batch * l * p2;
        let sh = l * h;
        let sp = l * p2;
        let t = backend.threads();
        let ex = backend.executor();
        let bidir = self.c_tilde.len() == 2;
        let SsmBuffers {
            bu_re, bu_im, bu_rev_re, bu_rev_im, a_tv_re, a_tv_im, dts_rev, scan, ..
        } = ssm;
        grow(bu_re, np);
        grow(bu_im, np);

        // drive: bu = B̃ u, per sequence in parallel, straight into planes
        par_zip2(ex, t, u, sh, bu_re, sp, bu_im, sp, batch, |_, useq, br, bi| {
            self.drive_seq_planar(useq, l, br, bi);
        });

        // The TI discretization comes from the workspace cache in planar
        // form — the hot path never transposes interleaved↔planar.
        match dts {
            None => {
                let d = ti_disc(disc, slot, &self.lambda, &self.log_dt, timescale);
                par_zip2(ex, t, u, sh, bu_re, sp, bu_im, sp, batch, |_, _, br, bi| {
                    Self::scale_seq_planar(br, bi, &d.f_re, &d.f_im, l, p2);
                });
                backend.scan_batch_ti_planar(
                    &d.a_re,
                    &d.a_im,
                    &mut bu_re[..np],
                    &mut bu_im[..np],
                    batch,
                    l,
                    p2,
                    scan,
                );
            }
            Some(dts) => {
                assert_eq!(dts.len(), batch * l);
                // base Δt served from the same value-validated cache entry
                // (it used to be rebuilt per batch — ROADMAP item)
                let d = ti_disc(disc, slot, &self.lambda, &self.log_dt, timescale);
                let base_dt = &d.base_dt;
                grow(a_tv_re, np);
                grow(a_tv_im, np);
                par_zip4(
                    ex, t, dts, l, a_tv_re, sp, a_tv_im, sp, bu_re, sp, bu_im, sp, batch,
                    |_, dseq, ar, ai, br, bi| {
                        // the one shared TV discretize+scale row pass —
                        // also what the fused tile pipeline runs
                        self.tv_disc_scale_rows(base_dt, dseq, l, ar, ai, br, bi);
                    },
                );
                backend.scan_batch_tv_planar(
                    &a_tv_re[..np],
                    &a_tv_im[..np],
                    &mut bu_re[..np],
                    &mut bu_im[..np],
                    batch,
                    l,
                    p2,
                    scan,
                );
            }
        }

        // forward projection; for unidirectional layers the feedthrough is
        // folded in here (matching the original projection → D order)
        {
            let xr = &bu_re[..np];
            let xi = &bu_im[..np];
            par_zip(ex, t, xr, sp, y, sh, batch, |i, xrseq, yseq| {
                yseq.fill(0.0);
                self.project_seq_planar(xrseq, &xi[i * sp..(i + 1) * sp], l, 0, false, yseq);
                if !bidir {
                    self.feedthrough_seq(&u[i * sh..(i + 1) * sh], l, yseq);
                }
            });
        }

        if bidir {
            // backward pass: scan the reversed drive, project back in
            // natural order. Under irregular sampling the multipliers
            // reverse *with* the drive (reversed Δt through the shared TV
            // row pass), so scan step k pairs Λ̄, f and B̃u from source
            // row l−1−k — the L2 reference semantics, fixture-pinned by
            // tests/parity_fixtures.rs.
            let d = ti_disc(disc, slot, &self.lambda, &self.log_dt, timescale);
            grow(bu_rev_re, np);
            grow(bu_rev_im, np);
            match dts {
                None => {
                    par_zip2(
                        ex, t, u, sh, bu_rev_re, sp, bu_rev_im, sp, batch,
                        |_, useq, br, bi| {
                            self.drive_rev_seq_planar(useq, l, Some(&d.f64s), br, bi);
                        },
                    );
                    backend.scan_batch_ti_planar(
                        &d.a_re,
                        &d.a_im,
                        &mut bu_rev_re[..np],
                        &mut bu_rev_im[..np],
                        batch,
                        l,
                        p2,
                        scan,
                    );
                }
                Some(dts) => {
                    let base_dt = &d.base_dt;
                    grow(dts_rev, batch * l);
                    for b in 0..batch {
                        for k in 0..l {
                            dts_rev[b * l + k] = dts[b * l + (l - 1 - k)];
                        }
                    }
                    par_zip2(
                        ex, t, u, sh, bu_rev_re, sp, bu_rev_im, sp, batch,
                        |_, useq, br, bi| {
                            self.drive_rev_seq_planar(useq, l, None, br, bi);
                        },
                    );
                    // multiplier planes: reuse the forward pass's a_tv
                    // scratch (its values are dead once the forward scan
                    // ran); the row pass is the same one the forward
                    // direction and the fused tiles run.
                    par_zip4(
                        ex,
                        t,
                        &dts_rev[..batch * l],
                        l,
                        a_tv_re,
                        sp,
                        a_tv_im,
                        sp,
                        bu_rev_re,
                        sp,
                        bu_rev_im,
                        sp,
                        batch,
                        |_, dseq, ar, ai, br, bi| {
                            self.tv_disc_scale_rows(base_dt, dseq, l, ar, ai, br, bi);
                        },
                    );
                    backend.scan_batch_tv_planar(
                        &a_tv_re[..np],
                        &a_tv_im[..np],
                        &mut bu_rev_re[..np],
                        &mut bu_rev_im[..np],
                        batch,
                        l,
                        p2,
                        scan,
                    );
                }
            }
            let xr = &bu_rev_re[..np];
            let xi = &bu_rev_im[..np];
            par_zip(ex, t, xr, sp, y, sh, batch, |i, xrseq, yseq| {
                self.project_seq_planar(xrseq, &xi[i * sp..(i + 1) * sp], l, 1, true, yseq);
                self.feedthrough_seq(&u[i * sh..(i + 1) * sh], l, yseq);
            });
        }
    }

    /// The interleaved `[C32]` SSM path — the reference oracle the planar
    /// default is validated against.
    #[allow(clippy::too_many_arguments)]
    fn apply_ssm_interleaved(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        backend: &dyn ScanBackend,
        slot: usize,
        disc: &mut Vec<Vec<TiDisc>>,
        ssm: &mut SsmBuffers,
        y: &mut [f32],
    ) {
        let (h, p2) = (self.h, self.p2);
        let np = batch * l * p2;
        let sh = l * h;
        let sp = l * p2;
        let t = backend.threads();
        let ex = backend.executor();
        let bidir = self.c_tilde.len() == 2;
        let SsmBuffers { bu, bu_rev, a_tv, scan, .. } = ssm;
        grow(bu, np);

        // drive: bu = B̃ u, per sequence in parallel
        par_zip(ex, t, u, sh, bu, sp, batch, |_, useq, buseq| {
            self.drive_seq(useq, l, buseq);
        });

        // The TI discretization (shared by the main path when dts is None
        // and by the backward direction of bidirectional layers) comes from
        // the workspace cache: repeated same-timescale batches skip the
        // exp-heavy recompute entirely.
        match dts {
            None => {
                let d = ti_disc(disc, slot, &self.lambda, &self.log_dt, timescale);
                par_zip(ex, t, u, sh, bu, sp, batch, |_, _, buseq| {
                    Self::scale_seq(buseq, &d.f32s, l, p2);
                });
                backend.scan_batch_ti(&d.a32, &mut bu[..np], batch, l, p2, scan);
            }
            Some(dts) => {
                assert_eq!(dts.len(), batch * l);
                // base Δt served from the same value-validated cache entry
                let d = ti_disc(disc, slot, &self.lambda, &self.log_dt, timescale);
                let base_dt = &d.base_dt;
                grow(a_tv, np);
                par_zip2(ex, t, dts, l, a_tv, sp, bu, sp, batch, |_, dseq, aseq, buseq| {
                    for k in 0..l {
                        for r in 0..p2 {
                            let dt = base_dt[r] * dseq[k] as f64;
                            let (lb, f) = discretize_one(self.lambda[r], dt, Method::Zoh);
                            aseq[k * p2 + r] = lb.to_c32();
                            buseq[k * p2 + r] = f.to_c32() * buseq[k * p2 + r];
                        }
                    }
                });
                backend.scan_batch_tv(&a_tv[..np], &mut bu[..np], batch, l, p2, scan);
            }
        }

        // forward projection; for unidirectional layers the feedthrough is
        // folded in here (matching the original projection → D order)
        par_zip(ex, t, &bu[..np], sp, y, sh, batch, |i, xs, yseq| {
            yseq.fill(0.0);
            self.project_seq(xs, l, 0, false, yseq);
            if !bidir {
                self.feedthrough_seq(&u[i * sh..(i + 1) * sh], l, yseq);
            }
        });

        if bidir {
            // backward pass: scan the reversed drive, project back in
            // natural order. Under irregular sampling the multipliers
            // reverse *with* the drive — the same per-row discretize+scale
            // ops as the forward TV loop above, indexed at source row
            // l−1−k, so this stays bit-for-bit with the planar paths.
            let d = ti_disc(disc, slot, &self.lambda, &self.log_dt, timescale);
            grow(bu_rev, np);
            match dts {
                None => {
                    par_zip(ex, t, u, sh, bu_rev, sp, batch, |_, useq, bseq| {
                        self.drive_rev_seq(useq, l, Some(&d.f64s), bseq);
                    });
                    backend.scan_batch_ti(&d.a32, &mut bu_rev[..np], batch, l, p2, scan);
                }
                Some(dts) => {
                    let base_dt = &d.base_dt;
                    par_zip(ex, t, u, sh, bu_rev, sp, batch, |_, useq, bseq| {
                        self.drive_rev_seq(useq, l, None, bseq);
                    });
                    grow(a_tv, np);
                    par_zip2(ex, t, dts, l, a_tv, sp, bu_rev, sp, batch, |_, dseq, aseq, bseq| {
                        for k in 0..l {
                            let dk = dseq[l - 1 - k] as f64;
                            for r in 0..p2 {
                                let dt = base_dt[r] * dk;
                                let (lb, f) = discretize_one(self.lambda[r], dt, Method::Zoh);
                                aseq[k * p2 + r] = lb.to_c32();
                                bseq[k * p2 + r] = f.to_c32() * bseq[k * p2 + r];
                            }
                        }
                    });
                    backend.scan_batch_tv(&a_tv[..np], &mut bu_rev[..np], batch, l, p2, scan);
                }
            }
            par_zip(ex, t, &bu_rev[..np], sp, y, sh, batch, |i, xs, yseq| {
                self.project_seq(xs, l, 1, true, yseq);
                self.feedthrough_seq(&u[i * sh..(i + 1) * sh], l, yseq);
            });
        }
    }

    /// Full layer over a packed batch, in place over `x` (B, L, H):
    /// pre-norm → SSM → GELU → gate → residual.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn apply_batch_core(
        &self,
        x: &mut Vec<f32>,
        v: &mut Vec<f32>,
        y: &mut Vec<f32>,
        y2: &mut Vec<f32>,
        ssm: &mut SsmBuffers,
        slot: usize,
        disc: &mut Vec<Vec<TiDisc>>,
        batch: usize,
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        backend: &dyn ScanBackend,
        policy: ScanPolicy,
    ) {
        let h = self.h;
        let n = batch * l * h;
        let sh = l * h;
        let t = backend.threads();
        let ex = backend.executor();
        if batch == 0 || l == 0 {
            return;
        }
        grow(v, n);
        grow(y, n);
        par_zip(ex, t, &x[..n], sh, v, sh, batch, |_, useq, vseq| {
            self.norm_seq(useq, l, vseq);
        });
        self.apply_ssm_core(
            &v[..n], batch, l, timescale, dts, backend, policy, slot, disc, ssm, y2, &mut y[..n],
        );
        // `v` (the normed input) is dead once the SSM ran; its rows serve
        // as the per-sequence GELU scratch so the gate stays alloc-free.
        par_zip2(ex, t, &y[..n], sh, x, sh, v, sh, batch, |_, yseq, xseq, vseq| {
            self.gate_residual_seq(yseq, xseq, l, vseq);
        });
    }

    // -- public entry points -----------------------------------------------

    /// Apply the SSM part (no norm/activation) to a packed (B, L, H)
    /// batch: returns y (B, L, H). `dts` is (B, L) per-step Δt multipliers
    /// for the irregular-sampling path (§6.3). Runs under the default
    /// [`ScanPolicy`] (fused auto-tiled, f32 state); use
    /// [`S5Layer::apply_ssm_batch_opts`] to pin tiling or state precision.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_ssm_batch(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        backend: &dyn ScanBackend,
        ws: &mut EngineWorkspace,
    ) -> Vec<f32> {
        let mut y = vec![0.0f32; batch * l * self.h];
        let EngineWorkspace { ssm, disc, y2, .. } = ws;
        self.apply_ssm_core(
            u, batch, l, timescale, dts, backend, ScanPolicy::default(), 0, disc, ssm, y2, &mut y,
        );
        y
    }

    /// [`S5Layer::apply_ssm_batch`] under explicit [`ForwardOptions`]
    /// (timescale, scan strategy, tiling / f64-state policy), writing
    /// into a caller-provided `y` (exactly B·L·H long) — the zero-alloc
    /// hot entry the benches A/B the fused and staged pipelines through.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_ssm_batch_opts_into(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        dts: Option<&[f32]>,
        opts: &ForwardOptions,
        ws: &mut EngineWorkspace,
        y: &mut [f32],
    ) {
        let EngineWorkspace { ssm, disc, y2, .. } = ws;
        self.apply_ssm_core(
            u,
            batch,
            l,
            opts.timescale,
            dts,
            opts.scan_backend(),
            opts.scan_policy(),
            0,
            disc,
            ssm,
            y2,
            y,
        );
    }

    /// [`S5Layer::apply_ssm_batch`] under explicit [`ForwardOptions`].
    pub fn apply_ssm_batch_opts(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        dts: Option<&[f32]>,
        opts: &ForwardOptions,
        ws: &mut EngineWorkspace,
    ) -> Vec<f32> {
        let mut y = vec![0.0f32; batch * l * self.h];
        self.apply_ssm_batch_opts_into(u, batch, l, dts, opts, ws, &mut y);
        y
    }

    /// Full layer over a packed (B, L, H) batch: pre-norm → SSM → GELU →
    /// gate → residual. Returns the layer output (B, L, H). Runs under
    /// the default [`ScanPolicy`]; see [`S5Layer::apply_batch_opts`].
    #[allow(clippy::too_many_arguments)]
    pub fn apply_batch(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        backend: &dyn ScanBackend,
        ws: &mut EngineWorkspace,
    ) -> Vec<f32> {
        let n = batch * l * self.h;
        assert_eq!(u.len(), n);
        let EngineWorkspace { x, v, y, y2, ssm, disc } = ws;
        grow(x, n);
        x[..n].copy_from_slice(u);
        self.apply_batch_core(
            x,
            v,
            y,
            y2,
            ssm,
            0,
            disc,
            batch,
            l,
            timescale,
            dts,
            backend,
            ScanPolicy::default(),
        );
        x[..n].to_vec()
    }

    /// [`S5Layer::apply_batch`] under explicit [`ForwardOptions`]
    /// (timescale, scan strategy, tiling / f64-state policy).
    pub fn apply_batch_opts(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        dts: Option<&[f32]>,
        opts: &ForwardOptions,
        ws: &mut EngineWorkspace,
    ) -> Vec<f32> {
        let n = batch * l * self.h;
        assert_eq!(u.len(), n);
        let EngineWorkspace { x, v, y, y2, ssm, disc } = ws;
        grow(x, n);
        x[..n].copy_from_slice(u);
        self.apply_batch_core(
            x,
            v,
            y,
            y2,
            ssm,
            0,
            disc,
            batch,
            l,
            opts.timescale,
            dts,
            opts.scan_backend(),
            opts.scan_policy(),
        );
        x[..n].to_vec()
    }

    /// Single-sequence SSM (batch-of-1 convenience): u (L×H) → y (L×H).
    ///
    /// `threads` selects the scan backend (≤ 1 = sequential). `dts`
    /// enables the irregular-sampling path (§6.3). Allocates a private
    /// workspace — hot paths should use [`S5Layer::apply_ssm_batch`].
    #[deprecated(
        since = "0.3.0",
        note = "positional legacy signature; use `apply_ssm_batch` with a \
                `ForwardOptions`-selected backend (see `ssm::api`)"
    )]
    pub fn apply_ssm(
        &self,
        u: &[f32],
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        threads: usize,
    ) -> Vec<f32> {
        let backend = legacy_backend(threads);
        let mut ws = EngineWorkspace::new();
        self.apply_ssm_batch(u, 1, l, timescale, dts, backend.as_ref(), &mut ws)
    }

    /// Single-sequence full layer (batch-of-1 convenience): pre-norm →
    /// SSM → GELU → gate → residual.
    #[deprecated(
        since = "0.3.0",
        note = "positional legacy signature; use `apply_batch` with a \
                `ForwardOptions`-selected backend (see `ssm::api`)"
    )]
    pub fn apply(
        &self,
        u: &[f32],
        l: usize,
        timescale: f64,
        dts: Option<&[f32]>,
        threads: usize,
    ) -> Vec<f32> {
        let backend = legacy_backend(threads);
        let mut ws = EngineWorkspace::new();
        self.apply_batch(u, 1, l, timescale, dts, backend.as_ref(), &mut ws)
    }

    /// Parameter count (matches the npz tensor sizes).
    pub fn param_count(&self) -> usize {
        2 * self.lambda.len()
            + 2 * self.b_tilde.len()
            + 2 * self.c_tilde.iter().map(|c| c.len()).sum::<usize>()
            + self.d.len()
            + self.log_dt.len()
            + self.gate_w.len()
            + self.norm_scale.len()
            + self.norm_bias.len()
    }
}

/// tanh-approximation GELU (matches `jax.nn.gelu` default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608f32; // sqrt(2/π)
    0.5 * x * (1.0 + ((C * (x + 0.044715 * x * x * x)).tanh()))
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// LayerNorm of one feature row.
pub fn layer_norm_row(x: &[f32], scale: &[f32], bias: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mu: f32 = x.iter().sum::<f32>() / n;
    let var: f32 = x.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-6).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mu) * inv * scale[i] + bias[i];
    }
}

/// A deep S5 model: encoder → layers → mean-pool → decoder (paper §G.1).
#[derive(Clone, Debug)]
pub struct S5Model {
    pub enc_w: Vec<f32>, // (H × d_in)
    pub enc_b: Vec<f32>,
    pub layers: Vec<S5Layer>,
    pub dec_w: Vec<f32>, // (classes × H)
    pub dec_b: Vec<f32>,
    pub d_in: usize,
    pub h: usize,
    pub classes: usize,
}

impl S5Model {
    pub fn init(
        d_in: usize,
        classes: usize,
        depth: usize,
        cfg: &S5Config,
        rng: &mut Rng,
    ) -> S5Model {
        let h = cfg.h;
        let se = 1.0 / (d_in as f64).sqrt();
        let sd = 1.0 / (h as f64).sqrt();
        S5Model {
            enc_w: (0..h * d_in).map(|_| (rng.normal() * se) as f32).collect(),
            enc_b: vec![0.0; h],
            layers: (0..depth).map(|_| S5Layer::init(cfg, rng)).collect(),
            dec_w: (0..classes * h).map(|_| (rng.normal() * sd) as f32).collect(),
            dec_b: vec![0.0; classes],
            d_in,
            h,
            classes,
        }
    }

    /// Linear encoder for one sequence: u (L × d_in) → x (L × H).
    pub(crate) fn encode_seq(&self, u: &[f32], l: usize, x: &mut [f32]) {
        let h = self.h;
        for k in 0..l {
            for r in 0..h {
                let mut acc = self.enc_b[r];
                for c in 0..self.d_in {
                    acc += self.enc_w[r * self.d_in + c] * u[k * self.d_in + c];
                }
                x[k * h + r] = acc;
            }
        }
    }

    /// Mean-pool + linear decoder for one sequence: x (L × H) → logits.
    /// `pooled` is caller-owned scratch (≥ `h` elements, any contents) so
    /// the decode stage stays alloc-free on the serving path.
    fn pool_decode_seq(&self, x: &[f32], l: usize, logits: &mut [f32], pooled: &mut [f32]) {
        let h = self.h;
        let pooled = &mut pooled[..h];
        pooled.fill(0.0);
        for k in 0..l {
            for r in 0..h {
                pooled[r] += x[k * h + r];
            }
        }
        for v in pooled.iter_mut() {
            *v /= l as f32;
        }
        for r in 0..self.classes {
            let mut acc = self.dec_b[r];
            for c in 0..h {
                acc += self.dec_w[r * h + c] * pooled[c];
            }
            logits[r] = acc;
        }
    }

    /// Batched forward: packed u (B, L, d_in) → logits written into `out`
    /// (B × classes). All large scratch lives in (and is reused from) the
    /// workspace; the backend parallelizes dense stages across sequences
    /// and the SSM stage across (sequence × direction) tile pipelines
    /// (fused default) or B × chunks (staged). Runs under the default
    /// [`ScanPolicy`] — [`S5Model::forward_batch_opts_into`] takes the
    /// policy from [`ForwardOptions`].
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_into(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        backend: &dyn ScanBackend,
        ws: &mut EngineWorkspace,
        out: &mut [f32],
    ) {
        self.forward_core(u, batch, l, timescale, backend, ScanPolicy::default(), ws, out);
    }

    /// [`S5Model::forward_batch_into`] under explicit [`ForwardOptions`]
    /// (timescale, scan strategy, tiling / f64-state policy) — the
    /// [`SequenceModel`] prefill surface routes through here.
    pub fn forward_batch_opts_into(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        opts: &ForwardOptions,
        ws: &mut EngineWorkspace,
        out: &mut [f32],
    ) {
        self.forward_core(
            u,
            batch,
            l,
            opts.timescale,
            opts.scan_backend(),
            opts.scan_policy(),
            ws,
            out,
        );
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_core(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        backend: &dyn ScanBackend,
        policy: ScanPolicy,
        ws: &mut EngineWorkspace,
        out: &mut [f32],
    ) {
        assert!(batch > 0 && l > 0, "empty batch/sequence");
        assert_eq!(u.len(), batch * l * self.d_in);
        assert_eq!(out.len(), batch * self.classes);
        let h = self.h;
        let n = batch * l * h;
        let t = backend.threads();
        let ex = backend.executor();
        let EngineWorkspace { x, v, y, y2, ssm, disc } = ws;
        grow(x, n);
        par_zip(ex, t, u, l * self.d_in, x, l * h, batch, |_, useq, xseq| {
            self.encode_seq(useq, l, xseq);
        });
        for (li, layer) in self.layers.iter().enumerate() {
            layer.apply_batch_core(
                x, v, y, y2, ssm, li, disc, batch, l, timescale, None, backend, policy,
            );
        }
        // `v` is dead after the last layer; lend its rows to the decoder
        // as the mean-pool scratch (alloc-free decode, lint L3's runtime
        // twin in tests/alloc_guard.rs).
        grow(v, n);
        par_zip2(ex, t, &x[..n], l * h, out, self.classes, v, l * h, batch, |_, xseq, oseq, vseq| {
            self.pool_decode_seq(xseq, l, oseq, vseq);
        });
    }

    /// Batched forward returning a fresh (B × classes) logits vector.
    pub fn forward_batch(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        backend: &dyn ScanBackend,
        ws: &mut EngineWorkspace,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; batch * self.classes];
        self.forward_batch_into(u, batch, l, timescale, backend, ws, &mut out);
        out
    }

    /// Logits for one sequence u (L × d_in) — batch-of-1 convenience that
    /// allocates a private workspace; hot paths should hold an
    /// [`EngineWorkspace`] and call [`S5Model::forward_batch_into`].
    #[deprecated(
        since = "0.3.0",
        note = "positional legacy signature; use `SequenceModel::prefill` \
                with a `Batch` view (see `ssm::api`)"
    )]
    pub fn forward(&self, u: &[f32], l: usize, timescale: f64, threads: usize) -> Vec<f32> {
        let backend = legacy_backend(threads);
        let mut ws = EngineWorkspace::new();
        self.forward_batch(u, 1, l, timescale, backend.as_ref(), &mut ws)
    }

    pub fn param_count(&self) -> usize {
        self.enc_w.len()
            + self.enc_b.len()
            + self.dec_w.len()
            + self.dec_b.len()
            + self.layers.iter().map(|l| l.param_count()).sum::<usize>()
    }

    /// True when every layer is unidirectional (a bidirectional layer
    /// needs the future by construction, so the stack cannot stream).
    pub fn streamable(&self) -> bool {
        self.layers.iter().all(|l| l.c_tilde.len() == 1)
    }
}

impl SequenceModel for S5Model {
    fn spec(&self) -> ModelSpec {
        ModelSpec {
            name: "s5",
            d_input: self.d_in,
            d_output: self.classes,
            streamable: self.streamable(),
        }
    }

    fn prefill_into(
        &self,
        batch: Batch<'_>,
        opts: &ForwardOptions,
        ws: &mut EngineWorkspace,
        out: &mut [f32],
    ) {
        assert_eq!(batch.width(), self.d_in, "batch width != model d_input");
        self.forward_batch_opts_into(batch.data(), batch.batch(), batch.len(), opts, ws, out);
    }

    fn make_state(&self, opts: &ForwardOptions) -> SessionState {
        assert!(self.streamable(), "bidirectional layers cannot stream");
        let dtype = opts.scan_policy().storage_dtype();
        SessionState::new(S5StreamState::with_dtype(self, opts.timescale, dtype))
    }

    fn reset_state(&self, state: &mut SessionState) {
        state
            .downcast_mut::<S5StreamState>()
            .expect("state is not an S5StreamState")
            .reset();
    }

    fn step(
        &self,
        state: &mut SessionState,
        u: &[f32],
        dt: Option<f32>,
        opts: &ForwardOptions,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; self.classes];
        self.step_into(state, u, dt, opts, &mut out);
        out
    }

    /// Allocation-free step: push runs through the stream state's
    /// workspace rows and the logits land in `out`, so after warmup a
    /// steady-state step performs zero heap allocations (pinned by the
    /// counting-allocator harness in `tests/alloc_guard.rs`).
    fn step_into(
        &self,
        state: &mut SessionState,
        u: &[f32],
        dt: Option<f32>,
        opts: &ForwardOptions,
        out: &mut [f32],
    ) {
        let st = state
            .downcast_mut::<S5StreamState>()
            .expect("state is not an S5StreamState");
        st.push(self, u, opts.timescale, dt);
        st.logits_into(self, out);
    }

    /// Prefill fast path: advance the layer stack and the pool without
    /// paying the classifier-head projection per swallowed token.
    fn advance(
        &self,
        state: &mut SessionState,
        u: &[f32],
        dt: Option<f32>,
        opts: &ForwardOptions,
    ) {
        state
            .downcast_mut::<S5StreamState>()
            .expect("state is not an S5StreamState")
            .push(self, u, opts.timescale, dt);
    }

    /// Chunked prefill: swallow a whole (L, d_in) prefix through the
    /// fused tile pipeline — one drive/scan/projection pipeline per
    /// layer, resuming from (and writing back) the stream's per-layer
    /// state — instead of L per-token steps. Bit-for-bit equal to the
    /// step-by-step replay (see [`S5StreamState::push_chunk`]).
    fn advance_batch(
        &self,
        state: &mut SessionState,
        tokens: &[f32],
        l: usize,
        opts: &ForwardOptions,
    ) {
        state
            .downcast_mut::<S5StreamState>()
            .expect("state is not an S5StreamState")
            .push_chunk(self, tokens, l, opts);
    }
}

// ---------------------------------------------------------------------------
// Native checkpoint import/export (npz, no PJRT required)
// ---------------------------------------------------------------------------

use crate::runtime::npz::NpzStore;

impl S5Model {
    /// Build a model from a named parameter store (a `<preset>_init.npz`
    /// or trained checkpoint as written by `python/compile/aot.py` /
    /// [`S5Model::to_param_store`]): tensors named
    /// `params.encoder.{w,bias}`, `params.layers.<i>.{lambda_re,lambda_im,
    /// b_re,b_im,c_re,c_im,d,log_dt,gate_w,norm_scale,norm_bias}`,
    /// `params.decoder.{w,bias}`. Shapes are cross-validated; a scalar
    /// `log_dt` (the Table-5 ablation) broadcasts over the state dimension.
    pub fn from_param_store(store: &NpzStore) -> anyhow::Result<S5Model> {
        use anyhow::Context;
        let f32s = |name: &str| -> anyhow::Result<Vec<f32>> {
            Ok(store
                .get(name)
                .with_context(|| format!("param {name:?} missing from store"))?
                .f32s()
                .with_context(|| format!("param {name:?} is not f32"))?
                .to_vec())
        };
        let dims = |name: &str| -> anyhow::Result<Vec<usize>> {
            Ok(store
                .get(name)
                .with_context(|| format!("param {name:?} missing from store"))?
                .dims
                .clone())
        };

        let enc_dims = dims("params.encoder.w")?;
        anyhow::ensure!(enc_dims.len() == 2, "encoder.w must be 2-D, got {enc_dims:?}");
        let (h, d_in) = (enc_dims[0], enc_dims[1]);
        let dec_dims = dims("params.decoder.w")?;
        anyhow::ensure!(
            dec_dims.len() == 2 && dec_dims[1] == h,
            "decoder.w must be (classes, {h}), got {dec_dims:?}"
        );
        let classes = dec_dims[0];

        let mut layers = Vec::new();
        loop {
            let li = layers.len();
            let pfx = format!("params.layers.{li}");
            if store.get(&format!("{pfx}.d")).is_none() {
                break;
            }
            let to_c64 = |re: &[f32], im: &[f32]| -> Vec<C64> {
                re.iter()
                    .zip(im)
                    .map(|(&r, &i)| C64::new(r as f64, i as f64))
                    .collect()
            };
            let lam_re = f32s(&format!("{pfx}.lambda_re"))?;
            let lam_im = f32s(&format!("{pfx}.lambda_im"))?;
            anyhow::ensure!(lam_re.len() == lam_im.len(), "{pfx}: lambda re/im mismatch");
            let p2 = lam_re.len();
            // for ≥ 2-D tensors the element count alone cannot catch a
            // transposed layout, so cross-check the stored dims too
            let expect_dims = |name: &str, want: &[usize]| -> anyhow::Result<()> {
                let got = dims(name)?;
                anyhow::ensure!(
                    got == want,
                    "{name}: stored shape {got:?} does not match expected {want:?}"
                );
                Ok(())
            };
            let b_re = f32s(&format!("{pfx}.b_re"))?;
            let b_im = f32s(&format!("{pfx}.b_im"))?;
            anyhow::ensure!(
                b_re.len() == p2 * h && b_im.len() == p2 * h,
                "{pfx}: B must be ({p2}, {h})"
            );
            expect_dims(&format!("{pfx}.b_re"), &[p2, h])?;
            expect_dims(&format!("{pfx}.b_im"), &[p2, h])?;
            let c_re = f32s(&format!("{pfx}.c_re"))?;
            let c_im = f32s(&format!("{pfx}.c_im"))?;
            anyhow::ensure!(
                c_re.len() == c_im.len() && !c_re.is_empty() && c_re.len() % (h * p2) == 0,
                "{pfx}: C must be (n_dir, {h}, {p2})"
            );
            let n_dir = c_re.len() / (h * p2);
            anyhow::ensure!(n_dir == 1 || n_dir == 2, "{pfx}: n_dir must be 1 or 2");
            for nm in [format!("{pfx}.c_re"), format!("{pfx}.c_im")] {
                let got = dims(&nm)?;
                anyhow::ensure!(
                    got == [n_dir, h, p2] || (n_dir == 1 && got == [h, p2]),
                    "{nm}: stored shape {got:?} does not match ({n_dir}, {h}, {p2})"
                );
            }
            let c_all = to_c64(&c_re, &c_im);
            let c_tilde: Vec<Vec<C64>> =
                c_all.chunks(h * p2).map(|c| c.to_vec()).collect();
            let d = f32s(&format!("{pfx}.d"))?;
            anyhow::ensure!(d.len() == h, "{pfx}: D must be ({h},)");
            let mut log_dt = f32s(&format!("{pfx}.log_dt"))?;
            if log_dt.len() == 1 {
                log_dt = vec![log_dt[0]; p2]; // scalar-Δ ablation broadcasts
            }
            anyhow::ensure!(log_dt.len() == p2, "{pfx}: log_dt must be ({p2},) or scalar");
            let gate_w = f32s(&format!("{pfx}.gate_w"))?;
            anyhow::ensure!(gate_w.len() == h * h, "{pfx}: gate_w must be ({h}, {h})");
            expect_dims(&format!("{pfx}.gate_w"), &[h, h])?;
            let norm_scale = f32s(&format!("{pfx}.norm_scale"))?;
            let norm_bias = f32s(&format!("{pfx}.norm_bias"))?;
            anyhow::ensure!(
                norm_scale.len() == h && norm_bias.len() == h,
                "{pfx}: norm params must be ({h},)"
            );
            layers.push(S5Layer {
                lambda: to_c64(&lam_re, &lam_im),
                b_tilde: to_c64(&b_re, &b_im),
                c_tilde,
                d,
                log_dt,
                gate_w,
                norm_scale,
                norm_bias,
                h,
                p2,
            });
        }
        anyhow::ensure!(!layers.is_empty(), "store has no params.layers.0.* tensors");
        // a partial checkpoint (e.g. layer N present but missing its `.d`)
        // must fail loudly, not silently load a shallower model
        for name in store.names() {
            if let Some(rest) = name.strip_prefix("params.layers.") {
                let idx: usize = rest
                    .split('.')
                    .next()
                    .and_then(|s| s.parse().ok())
                    .with_context(|| format!("unparsable layer tensor name {name:?}"))?;
                anyhow::ensure!(
                    idx < layers.len(),
                    "checkpoint has tensors for layer {idx} ({name:?}) but layer \
                     {} is incomplete (missing its `.d` tensor)",
                    layers.len()
                );
            }
        }

        let enc_b = f32s("params.encoder.bias")?;
        anyhow::ensure!(enc_b.len() == h, "encoder.bias must be ({h},), got {}", enc_b.len());
        let dec_b = f32s("params.decoder.bias")?;
        anyhow::ensure!(
            dec_b.len() == classes,
            "decoder.bias must be ({classes},), got {}",
            dec_b.len()
        );
        Ok(S5Model {
            enc_w: f32s("params.encoder.w")?,
            enc_b,
            layers,
            dec_w: f32s("params.decoder.w")?,
            dec_b,
            d_in,
            h,
            classes,
        })
    }

    /// Export the model as a named parameter store with the same tensor
    /// names [`S5Model::from_param_store`] reads — `store.save(path)`
    /// writes a checkpoint the native server can serve back.
    ///
    /// Complex parameters are stored as f32 re/im planes (the on-disk
    /// format), so a load → save → load round trip is exact while the
    /// first export of a freshly initialized (f64) model rounds once.
    pub fn to_param_store(&self) -> NpzStore {
        let mut store = NpzStore::new();
        let (h, d_in, classes) = (self.h, self.d_in, self.classes);
        store.insert_f32("params.encoder.w", &[h, d_in], self.enc_w.clone());
        store.insert_f32("params.encoder.bias", &[h], self.enc_b.clone());
        store.insert_f32("params.decoder.w", &[classes, h], self.dec_w.clone());
        store.insert_f32("params.decoder.bias", &[classes], self.dec_b.clone());
        for (li, layer) in self.layers.iter().enumerate() {
            let pfx = format!("params.layers.{li}");
            let p2 = layer.p2;
            let re = |v: &[C64]| v.iter().map(|z| z.re as f32).collect::<Vec<f32>>();
            let im = |v: &[C64]| v.iter().map(|z| z.im as f32).collect::<Vec<f32>>();
            let n_dir = layer.c_tilde.len();
            let c_flat: Vec<C64> = layer.c_tilde.concat();
            store.insert_f32(&format!("{pfx}.lambda_re"), &[p2], re(&layer.lambda));
            store.insert_f32(&format!("{pfx}.lambda_im"), &[p2], im(&layer.lambda));
            store.insert_f32(&format!("{pfx}.b_re"), &[p2, h], re(&layer.b_tilde));
            store.insert_f32(&format!("{pfx}.b_im"), &[p2, h], im(&layer.b_tilde));
            store.insert_f32(&format!("{pfx}.c_re"), &[n_dir, h, p2], re(&c_flat));
            store.insert_f32(&format!("{pfx}.c_im"), &[n_dir, h, p2], im(&c_flat));
            store.insert_f32(&format!("{pfx}.d"), &[h], layer.d.clone());
            store.insert_f32(&format!("{pfx}.log_dt"), &[p2], layer.log_dt.clone());
            store.insert_f32(&format!("{pfx}.gate_w"), &[h, h], layer.gate_w.clone());
            store.insert_f32(&format!("{pfx}.norm_scale"), &[h], layer.norm_scale.clone());
            store.insert_f32(&format!("{pfx}.norm_bias"), &[h], layer.norm_bias.clone());
        }
        store
    }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy wrappers are the per-sequence oracles here
mod tests {
    use super::*;
    use crate::testing::prop;

    fn layer(h: usize, p: usize, j: usize, bidir: bool) -> S5Layer {
        let cfg = S5Config { h, p, j, bidir, ..Default::default() };
        S5Layer::init(&cfg, &mut Rng::new(1))
    }

    #[test]
    fn layer_output_shape_and_finite() {
        let l = 64;
        let lp = layer(8, 8, 1, false);
        let mut rng = Rng::new(2);
        let u = rng.normal_vec_f32(l * 8);
        let y = lp.apply(&u, l, 1.0, None, 1);
        assert_eq!(y.len(), l * 8);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causal_without_bidir() {
        let l = 40;
        let lp = layer(6, 8, 1, false);
        let mut rng = Rng::new(3);
        let mut u = rng.normal_vec_f32(l * 6);
        let y1 = lp.apply(&u, l, 1.0, None, 1);
        u[(l - 1) * 6] += 5.0;
        let y2 = lp.apply(&u, l, 1.0, None, 1);
        for k in 0..(l - 1) * 6 {
            assert!((y1[k] - y2[k]).abs() < 1e-5, "leak at {k}");
        }
    }

    #[test]
    fn bidir_is_not_causal() {
        let l = 40;
        let lp = layer(6, 8, 1, true);
        let mut rng = Rng::new(4);
        let mut u = rng.normal_vec_f32(l * 6);
        let y1 = lp.apply(&u, l, 1.0, None, 1);
        u[(l - 1) * 6] += 5.0;
        let y2 = lp.apply(&u, l, 1.0, None, 1);
        let early_diff: f32 = (0..6).map(|c| (y1[c] - y2[c]).abs()).sum();
        assert!(early_diff > 1e-6);
    }

    #[test]
    fn prop_threads_agree() {
        prop::check("layer threads invariance", 10, |g| {
            let l = 16 + g.below(200);
            let lp = layer(4, 8, 1, false);
            let u: Vec<f32> = (0..l * 4).map(|_| g.normal() as f32).collect();
            let y1 = lp.apply(&u, l, 1.0, None, 1);
            let y4 = lp.apply(&u, l, 1.0, None, 4);
            prop::close_slice_f32(&y1, &y4, 1e-4)
        });
    }

    #[test]
    fn timescale_equals_dt_shift() {
        // ρ·Δ == exp(logΔ + ln ρ): zero-shot resampling identity (§6.2).
        let mut lp = layer(4, 8, 1, false);
        let l = 32;
        let mut rng = Rng::new(5);
        let u = rng.normal_vec_f32(l * 4);
        let y1 = lp.apply_ssm(&u, l, 2.0, None, 1);
        for ld in lp.log_dt.iter_mut() {
            *ld += (2.0f32).ln();
        }
        let y2 = lp.apply_ssm(&u, l, 1.0, None, 1);
        prop::close_slice_f32(&y1, &y2, 1e-4).unwrap();
    }

    #[test]
    fn variable_dt_unit_matches_fixed() {
        let lp = layer(4, 8, 2, false);
        let l = 25;
        let mut rng = Rng::new(6);
        let u = rng.normal_vec_f32(l * 4);
        let fixed = lp.apply_ssm(&u, l, 1.0, None, 1);
        let var = lp.apply_ssm(&u, l, 1.0, Some(&vec![1.0; l]), 1);
        prop::close_slice_f32(&fixed, &var, 1e-4).unwrap();
    }

    #[test]
    fn model_forward_shape() {
        let cfg = S5Config { h: 16, p: 16, j: 2, ..Default::default() };
        let m = S5Model::init(2, 10, 2, &cfg, &mut Rng::new(7));
        let mut rng = Rng::new(8);
        let u = rng.normal_vec_f32(50 * 2);
        let logits = m.forward(&u, 50, 1.0, 1);
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(m.param_count() > 1000);
    }

    /// The core batched-engine guarantee: a packed batch of B sequences
    /// produces the same per-sequence outputs as B independent forwards,
    /// for every backend and for B below/at/above the thread count.
    #[test]
    fn prop_batched_layer_matches_per_sequence() {
        prop::check("layer batch ≡ per-sequence", 8, |g| {
            let batch = 1 + g.below(5);
            let l = 4 + g.below(60);
            let bidir = g.coin(0.5);
            let lp = layer(4, 8, 1, bidir);
            let u: Vec<f32> = (0..batch * l * 4).map(|_| g.normal() as f32).collect();
            for threads in [1usize, 3] {
                let backend = super::legacy_backend(threads);
                let mut ws = EngineWorkspace::new();
                let got = lp.apply_batch(&u, batch, l, 1.0, None, backend.as_ref(), &mut ws);
                for bi in 0..batch {
                    let useq = &u[bi * l * 4..(bi + 1) * l * 4];
                    let want = lp.apply(useq, l, 1.0, None, 1);
                    prop::close_slice_f32(&want, &got[bi * l * 4..(bi + 1) * l * 4], 1e-4)
                        .map_err(|e| format!("bidir={bidir} t={threads} seq {bi}: {e}"))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_batched_ssm_with_dts_matches_per_sequence() {
        prop::check("ssm batch (B,L) dts ≡ per-sequence", 6, |g| {
            let batch = 1 + g.below(4);
            let l = 4 + g.below(40);
            let lp = layer(4, 8, 1, false);
            let u: Vec<f32> = (0..batch * l * 4).map(|_| g.normal() as f32).collect();
            let dts: Vec<f32> = (0..batch * l)
                .map(|_| g.uniform_in(0.3, 2.5) as f32)
                .collect();
            let backend = super::legacy_backend(2);
            let mut ws = EngineWorkspace::new();
            let got =
                lp.apply_ssm_batch(&u, batch, l, 1.0, Some(&dts), backend.as_ref(), &mut ws);
            for bi in 0..batch {
                let useq = &u[bi * l * 4..(bi + 1) * l * 4];
                let dseq = &dts[bi * l..(bi + 1) * l];
                let want = lp.apply_ssm(useq, l, 1.0, Some(dseq), 1);
                prop::close_slice_f32(&want, &got[bi * l * 4..(bi + 1) * l * 4], 1e-4)
                    .map_err(|e| format!("seq {bi}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_batched_model_matches_per_sequence() {
        prop::check("model batch ≡ per-sequence", 6, |g| {
            let batch = 1 + g.below(6);
            let l = 8 + g.below(40);
            let cfg = S5Config { h: 8, p: 8, j: 1, ..Default::default() };
            let m = S5Model::init(2, 5, 2, &cfg, &mut Rng::new(42));
            let u: Vec<f32> = (0..batch * l * 2).map(|_| g.normal() as f32).collect();
            for threads in [1usize, 2, 4] {
                let backend = super::legacy_backend(threads);
                let mut ws = EngineWorkspace::new();
                let got = m.forward_batch(&u, batch, l, 1.0, backend.as_ref(), &mut ws);
                for bi in 0..batch {
                    let useq = &u[bi * l * 2..(bi + 1) * l * 2];
                    let want = m.forward(useq, l, 1.0, 1);
                    prop::close_slice_f32(&want, &got[bi * 5..(bi + 1) * 5], 1e-4)
                        .map_err(|e| format!("t={threads} seq {bi}: {e}"))?;
                }
            }
            Ok(())
        });
    }

    /// Workspace reuse: after a warm-up call at the largest shape, repeat
    /// forwards at the same or smaller shapes must not grow the workspace
    /// (the zero-steady-state-allocation contract), and must agree with a
    /// fresh-workspace run.
    #[test]
    fn workspace_reuse_is_stable_and_allocation_free() {
        let cfg = S5Config { h: 8, p: 8, j: 1, ..Default::default() };
        let m = S5Model::init(3, 4, 2, &cfg, &mut Rng::new(9));
        let backend = super::legacy_backend(2);
        let mut ws = EngineWorkspace::new();
        let mut rng = Rng::new(10);
        let (big_b, big_l) = (6, 48);
        let u_big = rng.normal_vec_f32(big_b * big_l * 3);
        let _ = m.forward_batch(&u_big, big_b, big_l, 1.0, backend.as_ref(), &mut ws);
        let high_water = ws.capacity_bytes();
        assert!(high_water > 0);
        for (b, l) in [(1usize, 16usize), (4, 48), (6, 48), (2, 30)] {
            let u = rng.normal_vec_f32(b * l * 3);
            let reused = m.forward_batch(&u, b, l, 1.0, backend.as_ref(), &mut ws);
            let mut fresh_ws = EngineWorkspace::new();
            let fresh = m.forward_batch(&u, b, l, 1.0, backend.as_ref(), &mut fresh_ws);
            prop::close_slice_f32(&reused, &fresh, 1e-6).unwrap();
            assert_eq!(
                ws.capacity_bytes(),
                high_water,
                "workspace reallocated at (B={b}, L={l})"
            );
        }
    }

    /// The planar pipelines equal the interleaved oracle exactly — layer,
    /// bidirectional layer, irregular-Δt SSM and full model. Two pins:
    /// the **staged** planar pipeline matches the interleaved oracle at
    /// the *same* strategy (identical FP ops in identical order, any
    /// thread budget), and the default **fused** pipeline matches the
    /// interleaved *sequential* oracle (the fused tile scans are
    /// sequential whatever the thread budget). Both bit-for-bit,
    /// asserted via a 0-tolerance compare.
    #[test]
    fn prop_planar_forward_matches_interleaved_oracle() {
        use crate::ssm::engine::Tiling;
        prop::check("planar ≡ interleaved (layer/model)", 6, |g| {
            let batch = 1 + g.below(5);
            let l = 4 + g.below(60);
            let bidir = g.coin(0.5);
            let lp = layer(4, 8, 1, bidir);
            let u: Vec<f32> = (0..batch * l * 4).map(|_| g.normal() as f32).collect();
            let dts: Vec<f32> = (0..batch * l).map(|_| g.uniform_in(0.3, 2.5) as f32).collect();
            let seq_oracle = ForwardOptions::new().with_scan(1, ScanLayout::Interleaved);
            for threads in [1usize, 3] {
                let staged = ForwardOptions::new()
                    .with_threads(threads)
                    .with_tiling(Tiling::Staged);
                let fused = ForwardOptions::new().with_threads(threads);
                let oracle = ForwardOptions::new().with_scan(threads, ScanLayout::Interleaved);
                let mut ws_p = EngineWorkspace::new();
                let mut ws_f = EngineWorkspace::new();
                let mut ws_i = EngineWorkspace::new();
                let mut ws_s = EngineWorkspace::new();
                let want = lp.apply_batch_opts(&u, batch, l, None, &oracle, &mut ws_i);
                let got = lp.apply_batch_opts(&u, batch, l, None, &staged, &mut ws_p);
                prop::close_slice_f32(&want, &got, 0.0)
                    .map_err(|e| format!("staged bidir={bidir} t={threads}: {e}"))?;
                let want_seq = lp.apply_batch_opts(&u, batch, l, None, &seq_oracle, &mut ws_s);
                let got = lp.apply_batch_opts(&u, batch, l, None, &fused, &mut ws_f);
                prop::close_slice_f32(&want_seq, &got, 0.0)
                    .map_err(|e| format!("fused bidir={bidir} t={threads}: {e}"))?;
                if !bidir {
                    let want =
                        lp.apply_ssm_batch_opts(&u, batch, l, Some(&dts), &oracle, &mut ws_i);
                    let got =
                        lp.apply_ssm_batch_opts(&u, batch, l, Some(&dts), &staged, &mut ws_p);
                    prop::close_slice_f32(&want, &got, 0.0)
                        .map_err(|e| format!("staged ssm dts t={threads}: {e}"))?;
                    let want =
                        lp.apply_ssm_batch_opts(&u, batch, l, Some(&dts), &seq_oracle, &mut ws_s);
                    let got =
                        lp.apply_ssm_batch_opts(&u, batch, l, Some(&dts), &fused, &mut ws_f);
                    prop::close_slice_f32(&want, &got, 0.0)
                        .map_err(|e| format!("fused ssm dts t={threads}: {e}"))?;
                }
            }
            let cfg = S5Config { h: 8, p: 8, j: 1, ..Default::default() };
            let m = S5Model::init(2, 5, 2, &cfg, &mut Rng::new(13));
            let mu: Vec<f32> = (0..batch * l * 2).map(|_| g.normal() as f32).collect();
            let mut ws_p = EngineWorkspace::new();
            let mut ws_i = EngineWorkspace::new();
            let mut out_p = vec![0.0f32; batch * 5];
            let mut out_i = vec![0.0f32; batch * 5];
            m.forward_batch_opts_into(
                &mu,
                batch,
                l,
                &ForwardOptions::new().with_threads(2),
                &mut ws_p,
                &mut out_p,
            );
            m.forward_batch_opts_into(&mu, batch, l, &seq_oracle, &mut ws_i, &mut out_i);
            prop::close_slice_f32(&out_i, &out_p, 0.0).map_err(|e| format!("model: {e}"))
        });
    }

    /// The irregular-Δt path serves base Δt from the per-layer cache: a
    /// repeat TV batch reuses the same cache entry (no per-batch rebuild)
    /// and reproduces the same output.
    #[test]
    fn tv_base_dt_is_cached_across_batches() {
        let lp = layer(4, 8, 1, false);
        let l = 20;
        let mut rng = Rng::new(14);
        let u = rng.normal_vec_f32(l * 4);
        let dts = rng.uniform_vec_f32(l, 0.3, 2.5);
        let backend = super::legacy_backend(1);
        let mut ws = EngineWorkspace::new();
        let y1 = lp.apply_ssm_batch(&u, 1, l, 1.0, Some(&dts), backend.as_ref(), &mut ws);
        assert_eq!(ws.disc[0].len(), 1, "TV path must populate the TI cache slot");
        let water = ws.capacity_bytes();
        let y2 = lp.apply_ssm_batch(&u, 1, l, 1.0, Some(&dts), backend.as_ref(), &mut ws);
        assert_eq!(y1, y2);
        assert_eq!(ws.disc[0].len(), 1, "repeat TV batch must hit the cache");
        assert_eq!(ws.capacity_bytes(), water, "repeat TV batch reallocated");
    }

    /// The fused path's acceptance contract on memory: the scan-facing
    /// buffers ([`SsmBuffers`]) reach a high-water mark that is
    /// **independent of L** (it grows only with the tile length), and
    /// steady-state fused forwards allocate nothing — while the staged
    /// oracle's scan buffers grow linearly with L.
    #[test]
    fn fused_ssm_buffers_are_l_independent_and_alloc_free() {
        use crate::ssm::engine::Tiling;
        let lp = layer(8, 16, 1, true); // bidirectional: both directions + y2
        let opts = ForwardOptions::new().with_threads(2).with_tile(16);
        let mut ws = EngineWorkspace::new();
        let mut rng = Rng::new(33);
        let u1 = rng.normal_vec_f32(2 * 64 * 8);
        let _ = lp.apply_batch_opts(&u1, 2, 64, None, &opts, &mut ws);
        let ssm_water = ws.ssm_capacity_bytes();
        assert!(ssm_water > 0);
        // 4× longer sequences: the scan-facing buffers must not grow
        let u2 = rng.normal_vec_f32(2 * 256 * 8);
        let _ = lp.apply_batch_opts(&u2, 2, 256, None, &opts, &mut ws);
        assert_eq!(
            ws.ssm_capacity_bytes(),
            ssm_water,
            "fused SsmBuffers grew with L (the O(B·T·P) contract)"
        );
        // steady state: repeating the shape allocates nothing anywhere
        let water = ws.capacity_bytes();
        let _ = lp.apply_batch_opts(&u2, 2, 256, None, &opts, &mut ws);
        assert_eq!(ws.capacity_bytes(), water, "steady-state fused forward allocated");
        // a longer tile is allowed to grow the envelope — T, not L
        let opts_big = ForwardOptions::new().with_threads(2).with_tile(32);
        let _ = lp.apply_batch_opts(&u2, 2, 256, None, &opts_big, &mut ws);
        assert!(ws.ssm_capacity_bytes() > ssm_water, "envelope must scale with the tile");
        // contrast: the staged oracle materializes full (B, L, P2) planes
        let staged = ForwardOptions::new().with_threads(2).with_tiling(Tiling::Staged);
        let mut ws_s1 = EngineWorkspace::new();
        let mut ws_s2 = EngineWorkspace::new();
        let _ = lp.apply_batch_opts(&u1, 2, 64, None, &staged, &mut ws_s1);
        let _ = lp.apply_batch_opts(&u2, 2, 256, None, &staged, &mut ws_s2);
        assert!(
            ws_s2.ssm_capacity_bytes() > ws_s1.ssm_capacity_bytes(),
            "staged scan buffers should scale with L"
        );
    }

    /// The f64-state option: tile- and policy-invariant bit-for-bit (the
    /// carry never round-trips through f32), close to the f32 result on a
    /// short stable sequence, and panics on the interleaved oracle.
    #[test]
    fn f64_state_is_tile_invariant_and_tracks_f32() {
        use crate::ssm::engine::Tiling;
        let lp = layer(4, 8, 1, false);
        let l = 200;
        let mut rng = Rng::new(44);
        let u = rng.normal_vec_f32(l * 4);
        let dts = rng.uniform_vec_f32(l, 0.3, 2.5);
        for dts in [None, Some(&dts[..])] {
            let mut reference: Option<Vec<f32>> = None;
            for opts in [
                ForwardOptions::new().with_f64_state().with_tile(7),
                ForwardOptions::new().with_f64_state().with_tile(64),
                ForwardOptions::new().with_f64_state().with_threads(3).with_tile(7),
                ForwardOptions::new().with_f64_state().with_tiling(Tiling::Staged),
            ] {
                let mut ws = EngineWorkspace::new();
                let got = lp.apply_ssm_batch_opts(&u, 1, l, dts, &opts, &mut ws);
                match &reference {
                    None => reference = Some(got),
                    Some(want) => assert_eq!(want, &got, "f64 state must be tile-invariant"),
                }
            }
            let mut ws = EngineWorkspace::new();
            let f32_res = lp.apply_ssm_batch_opts(
                &u,
                1,
                l,
                dts,
                &ForwardOptions::new().with_tile(7),
                &mut ws,
            );
            prop::close_slice_f32(&f32_res, reference.as_ref().unwrap(), 1e-3).unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "planar layout")]
    fn f64_state_rejects_interleaved_oracle() {
        let lp = layer(4, 8, 1, false);
        let mut rng = Rng::new(45);
        let u = rng.normal_vec_f32(10 * 4);
        let opts = ForwardOptions::new()
            .with_scan(1, ScanLayout::Interleaved)
            .with_f64_state();
        let mut ws = EngineWorkspace::new();
        let _ = lp.apply_ssm_batch_opts(&u, 1, 10, None, &opts, &mut ws);
    }

    #[test]
    fn gelu_matches_reference_points() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-3);
    }
}
