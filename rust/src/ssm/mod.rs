//! State-space model core: the pure-Rust reference stack.
//!
//! This module reimplements, in Rust, the math that the L1/L2 Python layers
//! compile into the HLO artifacts — plus the S4/S4D baselines the paper
//! compares against. It serves three roles:
//!
//! 1. **Parity oracle** — `runtime` integration tests check the compiled
//!    HLO against [`s5`] on identical parameters (three-way agreement with
//!    the jnp oracle via the shared npz fixtures).
//! 2. **Benchmark subject** — the Table-4 runtime comparisons and the
//!    parallel-scan scaling studies (Prop. 1, Appendix C/H) run on these
//!    implementations, where we control every allocation.
//! 3. **Native initialization** — the Rust-side HiPPO construction mirrors
//!    `python/compile/hippo.py`, so experiments can instantiate fresh models
//!    without touching Python.

//!
//! The batched native inference engine ([`engine`]) plus the pluggable
//! scan strategies ([`scan::ScanBackend`]) thread a (B, L, H) batch
//! dimension through the whole stack — the CPU-side counterpart of the
//! `jax.vmap`-batched reference. The scan hot loop runs in the planar
//! (SoA) layout by default with the interleaved `C32` kernels retained as
//! the bit-for-bit reference oracle (see [`scan::ScanLayout`] and the
//! crate-level "Scan strategy selection" docs). The unified inference
//! surface over it is
//! [`api`]: the [`api::SequenceModel`] trait (typed [`api::Batch`] prefill
//! + streaming steps) implemented by S5 and the RNN baselines alike, and
//! the [`api::Session`] streaming API the server pools per connection.

pub mod api;
pub mod complexity;
pub mod discretize;
pub mod dtype;
pub mod engine;
pub mod hippo;
pub mod online;
pub mod rnn;
pub mod s4;
pub mod s5;
pub mod scan;
pub mod simd;
