//! Explicit lane-blocked planar kernels — the `simd` feature's hot path.
//!
//! The planar (SoA) layout was introduced (PR 3) so LLVM *could* vectorize
//! the scan hot loops; this module stops relying on the autovectorizer's
//! mood and writes the four hottest loop families as explicit fixed-width
//! lane blocks:
//!
//! 1. **drive Δt-scale** — [`scale_rows`] (complex `f ∘ bu` over re/im
//!    planes);
//! 2. **scan recurrence** — [`scan_row_step`] (previous-row form of the
//!    sequential kernels and the parallel local-scan phase) and
//!    [`scan_row_resume`] (carried-state form of the tile-resumable
//!    kernels);
//! 3. **combine** — [`combine_row`] (chunk-summary combine of the
//!    parallel scans) and [`fixup_row`] (the carry-propagating fixup
//!    phase);
//! 4. **projection accumulate** — [`project_row`] (2·Re(C̃x) with f64
//!    accumulators, blocked over output channels).
//!
//! ## Why not `core::simd`
//!
//! `core::simd` is still nightly-only and this crate builds on stable, so
//! the lanes are spelled as fixed-width `[f32; LANES]` / `[f64; PROJ_LANES]`
//! blocks over `try_into`-converted sub-slices: a load phase, an arithmetic
//! phase over the whole block, a store phase. Rust's default FP semantics
//! (no fast-math, no FMA contraction) mean LLVM lowers each block to the
//! corresponding packed vector ops without reassociating anything.
//!
//! ## Equivalence contract
//!
//! Every kernel here performs, **per element, the identical FP ops in the
//! identical order** as its scalar twin — the blocks only group
//! *independent* elements (the P state lanes, or independent output
//! channels whose private reductions keep their own accumulation order).
//! SIMD results are therefore bit-for-bit equal to the scalar oracle, and
//! enabling the `simd` feature (on by default) cannot disturb any of the
//! planar ≡ interleaved / fused ≡ staged bit-for-bit pins. The module
//! tests assert exact equality against inline scalar references, including
//! a long-L (64k-step) running-sum drift case; `tests/scan_matrix.rs`
//! additionally tolerance-pins the end-to-end forward against the f64
//! oracle at L = 64k, which would catch any numeric drift if a toolchain
//! ever broke the exactness assumption.
//!
//! The scalar loops stay in place in `scan.rs`/`s5.rs` under
//! `--no-default-features` (the oracle build CI exercises); the dispatch
//! is a `cfg!(feature = "simd")` branch at each call site, so both paths
//! type-check in every configuration.

use crate::num::C64;
use crate::ssm::dtype::{bf16_to_f32, f32_to_bf16, Bf16};

// s5:hot-begin — explicit-lane twins of the four hottest planar loops
// (plus their bf16-storage widen/narrow variants); strictly slice
// arithmetic over caller-owned planes (lint L3).

/// f32 lane width of the element-wise blocks (two AVX2 `f32x8` registers /
/// one AVX-512 register worth per re/im pair).
pub(crate) const LANES: usize = 8;

/// f64 accumulator lanes of the projection blocks.
pub(crate) const PROJ_LANES: usize = 4;

#[inline(always)]
fn load(s: &[f32], j: usize) -> [f32; LANES] {
    s[j..j + LANES].try_into().unwrap()
}

#[inline(always)]
fn store(d: &mut [f32], j: usize, v: &[f32; LANES]) {
    d[j..j + LANES].copy_from_slice(v);
}

/// Widen one lane block of bf16 storage to f32 (exact — bfloat16 is a
/// bit-prefix of binary32, so this lowers to a zero-extend + shift).
#[inline(always)]
fn load16(s: &[Bf16], j: usize) -> [f32; LANES] {
    let b: [Bf16; LANES] = s[j..j + LANES].try_into().unwrap();
    let mut v = [0.0f32; LANES];
    for t in 0..LANES {
        v[t] = bf16_to_f32(b[t]);
    }
    v
}

/// Narrow one computed f32 lane block into bf16 storage
/// (round-to-nearest-even per element).
#[inline(always)]
fn store16(d: &mut [Bf16], j: usize, v: &[f32; LANES]) {
    for t in 0..LANES {
        d[j + t] = f32_to_bf16(v[t]);
    }
}

/// `bu ← f ∘ bu` over `rows` planar (rows, p) re/im rows: the drive
/// Δt-scale. Per element: `br' = fr·br − fi·bi; bi' = fr·bi + fi·br` —
/// the exact op order of the scalar `scale_seq_planar`.
pub(crate) fn scale_rows(
    bur: &mut [f32],
    bui: &mut [f32],
    fr: &[f32],
    fi: &[f32],
    rows: usize,
    p: usize,
) {
    let pb = p - p % LANES;
    for k in 0..rows {
        let row = k * p;
        let mut j = 0;
        while j < pb {
            let (frv, fiv) = (load(fr, j), load(fi, j));
            let (br, bi) = (load(bur, row + j), load(bui, row + j));
            let mut nr = [0.0f32; LANES];
            let mut ni = [0.0f32; LANES];
            for t in 0..LANES {
                nr[t] = frv[t] * br[t] - fiv[t] * bi[t];
                ni[t] = frv[t] * bi[t] + fiv[t] * br[t];
            }
            store(bur, row + j, &nr);
            store(bui, row + j, &ni);
            j += LANES;
        }
        for j in pb..p {
            let br = bur[row + j];
            let bi = bui[row + j];
            bur[row + j] = fr[j] * br - fi[j] * bi;
            bui[row + j] = fr[j] * bi + fi[j] * br;
        }
    }
}

/// One scan-recurrence row in previous-row form:
/// `cur ← a ∘ prev + cur` (the row body of the sequential planar kernels
/// and of the parallel local-scan phase). All slices have length P.
#[inline]
pub(crate) fn scan_row_step(
    ar: &[f32],
    ai: &[f32],
    pr: &[f32],
    pi: &[f32],
    cr: &mut [f32],
    ci: &mut [f32],
) {
    let p = cr.len();
    let pb = p - p % LANES;
    let mut j = 0;
    while j < pb {
        let (av, bv) = (load(ar, j), load(ai, j));
        let (prv, piv) = (load(pr, j), load(pi, j));
        let (crv, civ) = (load(cr, j), load(ci, j));
        let mut nr = [0.0f32; LANES];
        let mut ni = [0.0f32; LANES];
        for t in 0..LANES {
            nr[t] = av[t] * prv[t] - bv[t] * piv[t] + crv[t];
            ni[t] = av[t] * piv[t] + bv[t] * prv[t] + civ[t];
        }
        store(cr, j, &nr);
        store(ci, j, &ni);
        j += LANES;
    }
    for j in pb..p {
        let nr = ar[j] * pr[j] - ai[j] * pi[j] + cr[j];
        let ni = ar[j] * pi[j] + ai[j] * pr[j] + ci[j];
        cr[j] = nr;
        ci[j] = ni;
    }
}

/// One scan-recurrence row in carried-state form:
/// `state ← a ∘ state + b`, with the new state also written to the row
/// (the row body of the tile-resumable planar kernels and of
/// `scan_step_planar_inplace`). All slices have length P.
#[inline]
pub(crate) fn scan_row_resume(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    br: &mut [f32],
    bi: &mut [f32],
) {
    let p = sr.len();
    let pb = p - p % LANES;
    let mut j = 0;
    while j < pb {
        let (av, bv) = (load(ar, j), load(ai, j));
        let (srv, siv) = (load(sr, j), load(si, j));
        let (brv, biv) = (load(br, j), load(bi, j));
        let mut nr = [0.0f32; LANES];
        let mut ni = [0.0f32; LANES];
        for t in 0..LANES {
            nr[t] = av[t] * srv[t] - bv[t] * siv[t] + brv[t];
            ni[t] = av[t] * siv[t] + bv[t] * srv[t] + biv[t];
        }
        store(sr, j, &nr);
        store(si, j, &ni);
        store(br, j, &nr);
        store(bi, j, &ni);
        j += LANES;
    }
    for j in pb..p {
        let nr = ar[j] * sr[j] - ai[j] * si[j] + br[j];
        let ni = ar[j] * si[j] + ai[j] * sr[j] + bi[j];
        sr[j] = nr;
        si[j] = ni;
        br[j] = nr;
        bi[j] = ni;
    }
}

/// One complex multiplier-accumulate row: `c ← a ∘ c` (the per-chunk
/// multiplier product of the TV parallel scan's local phase). All slices
/// have length P.
#[inline]
pub(crate) fn cmul_row(ar: &[f32], ai: &[f32], cr: &mut [f32], ci: &mut [f32]) {
    let p = cr.len();
    let pb = p - p % LANES;
    let mut j = 0;
    while j < pb {
        let (av, bv) = (load(ar, j), load(ai, j));
        let (crv, civ) = (load(cr, j), load(ci, j));
        let mut nr = [0.0f32; LANES];
        let mut ni = [0.0f32; LANES];
        for t in 0..LANES {
            nr[t] = av[t] * crv[t] - bv[t] * civ[t];
            ni[t] = av[t] * civ[t] + bv[t] * crv[t];
        }
        store(cr, j, &nr);
        store(ci, j, &ni);
        j += LANES;
    }
    for j in pb..p {
        let nr = ar[j] * cr[j] - ai[j] * ci[j];
        let ni = ar[j] * ci[j] + ai[j] * cr[j];
        cr[j] = nr;
        ci[j] = ni;
    }
}

/// One chunk-summary combine row: `st ← apw ∘ st + last` (phase 2 of the
/// chunked parallel scans). All slices have length P.
#[inline]
pub(crate) fn combine_row(
    apw_r: &[f32],
    apw_i: &[f32],
    last_r: &[f32],
    last_i: &[f32],
    st_r: &mut [f32],
    st_i: &mut [f32],
) {
    let p = st_r.len();
    let pb = p - p % LANES;
    let mut j = 0;
    while j < pb {
        let (av, bv) = (load(apw_r, j), load(apw_i, j));
        let (lrv, liv) = (load(last_r, j), load(last_i, j));
        let (srv, siv) = (load(st_r, j), load(st_i, j));
        let mut nr = [0.0f32; LANES];
        let mut ni = [0.0f32; LANES];
        for t in 0..LANES {
            nr[t] = av[t] * srv[t] - bv[t] * siv[t] + lrv[t];
            ni[t] = av[t] * siv[t] + bv[t] * srv[t] + liv[t];
        }
        store(st_r, j, &nr);
        store(st_i, j, &ni);
        j += LANES;
    }
    for j in pb..p {
        let nr = apw_r[j] * st_r[j] - apw_i[j] * st_i[j] + last_r[j];
        let ni = apw_r[j] * st_i[j] + apw_i[j] * st_r[j] + last_i[j];
        st_r[j] = nr;
        st_i[j] = ni;
    }
}

/// One fixup row of the chunked parallel scans (phase 3): advance the
/// entering carry by the row's multiplier (`carry ← carry ∘ a`) and add
/// it into the row (`x += carry`). All slices have length P.
///
/// The TI scalar loop writes `carry·a` and the TV scalar loop writes
/// `a·carry`; IEEE-754 `*` and `+` are commutative bit-for-bit on the
/// finite values these kernels see, so this one body serves both.
#[inline]
pub(crate) fn fixup_row(
    ar: &[f32],
    ai: &[f32],
    cr: &mut [f32],
    ci: &mut [f32],
    xr: &mut [f32],
    xi: &mut [f32],
) {
    let p = cr.len();
    let pb = p - p % LANES;
    let mut j = 0;
    while j < pb {
        let (av, bv) = (load(ar, j), load(ai, j));
        let (crv, civ) = (load(cr, j), load(ci, j));
        let mut nr = [0.0f32; LANES];
        let mut ni = [0.0f32; LANES];
        for t in 0..LANES {
            nr[t] = crv[t] * av[t] - civ[t] * bv[t];
            ni[t] = crv[t] * bv[t] + civ[t] * av[t];
        }
        store(cr, j, &nr);
        store(ci, j, &ni);
        let (xrv, xiv) = (load(xr, j), load(xi, j));
        let mut sxr = [0.0f32; LANES];
        let mut sxi = [0.0f32; LANES];
        for t in 0..LANES {
            sxr[t] = xrv[t] + nr[t];
            sxi[t] = xiv[t] + ni[t];
        }
        store(xr, j, &sxr);
        store(xi, j, &sxi);
        j += LANES;
    }
    for j in pb..p {
        let nr = cr[j] * ar[j] - ci[j] * ai[j];
        let ni = cr[j] * ai[j] + ci[j] * ar[j];
        cr[j] = nr;
        ci[j] = ni;
        xr[j] += nr;
        xi[j] += ni;
    }
}

/// One projection row: `y[r] += 2·Re(C̃[r,·] · x)` for every output
/// channel r, blocked [`PROJ_LANES`] channels at a time with one private
/// f64 accumulator per channel. Each channel's reduction runs over the
/// P2 states in ascending order — exactly the scalar op order — so the
/// blocking never reassociates a sum.
pub(crate) fn project_row(
    ct: &[C64],
    xr: &[f32],
    xi: &[f32],
    y: &mut [f32],
    h: usize,
    p2: usize,
) {
    let hb = h - h % PROJ_LANES;
    let mut r = 0;
    while r < hb {
        let mut acc = [0.0f64; PROJ_LANES];
        for c in 0..p2 {
            let (xrc, xic) = (xr[c] as f64, xi[c] as f64);
            for t in 0..PROJ_LANES {
                let cv = ct[(r + t) * p2 + c];
                acc[t] += cv.re * xrc - cv.im * xic;
            }
        }
        for t in 0..PROJ_LANES {
            y[r + t] += 2.0 * acc[t] as f32;
        }
        r += PROJ_LANES;
    }
    for r in hb..h {
        let mut acc = 0.0f64;
        for c in 0..p2 {
            let cv = ct[r * p2 + c];
            acc += cv.re * xr[c] as f64 - cv.im * xi[c] as f64;
        }
        y[r] += 2.0 * acc as f32;
    }
}

// ---- bf16-storage twins -------------------------------------------------
//
// Same lane blocks, same per-element f32 op order — the only difference
// is a widen on load and a round-to-nearest-even narrow on store, exactly
// matching the generic scalar loops' `to_f32`/`from_f32` placement, so
// each bf16 lane kernel is bit-for-bit equal to its scalar twin too.

/// bf16 twin of [`scale_rows`]: widen the stored drive, scale in f32,
/// narrow-store.
pub(crate) fn scale_rows_bf16(
    bur: &mut [Bf16],
    bui: &mut [Bf16],
    fr: &[f32],
    fi: &[f32],
    rows: usize,
    p: usize,
) {
    let pb = p - p % LANES;
    for k in 0..rows {
        let row = k * p;
        let mut j = 0;
        while j < pb {
            let (frv, fiv) = (load(fr, j), load(fi, j));
            let (br, bi) = (load16(bur, row + j), load16(bui, row + j));
            let mut nr = [0.0f32; LANES];
            let mut ni = [0.0f32; LANES];
            for t in 0..LANES {
                nr[t] = frv[t] * br[t] - fiv[t] * bi[t];
                ni[t] = frv[t] * bi[t] + fiv[t] * br[t];
            }
            store16(bur, row + j, &nr);
            store16(bui, row + j, &ni);
            j += LANES;
        }
        for j in pb..p {
            let br = bf16_to_f32(bur[row + j]);
            let bi = bf16_to_f32(bui[row + j]);
            bur[row + j] = f32_to_bf16(fr[j] * br - fi[j] * bi);
            bui[row + j] = f32_to_bf16(fr[j] * bi + fi[j] * br);
        }
    }
}

/// bf16 twin of [`scan_row_resume`]: the carried state stays f32 across
/// the whole sequence (the compute dtype); only the emitted row narrows.
#[inline]
pub(crate) fn scan_row_resume_bf16(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    br: &mut [Bf16],
    bi: &mut [Bf16],
) {
    let p = sr.len();
    let pb = p - p % LANES;
    let mut j = 0;
    while j < pb {
        let (av, bv) = (load(ar, j), load(ai, j));
        let (srv, siv) = (load(sr, j), load(si, j));
        let (brv, biv) = (load16(br, j), load16(bi, j));
        let mut nr = [0.0f32; LANES];
        let mut ni = [0.0f32; LANES];
        for t in 0..LANES {
            nr[t] = av[t] * srv[t] - bv[t] * siv[t] + brv[t];
            ni[t] = av[t] * siv[t] + bv[t] * srv[t] + biv[t];
        }
        store(sr, j, &nr);
        store(si, j, &ni);
        store16(br, j, &nr);
        store16(bi, j, &ni);
        j += LANES;
    }
    for j in pb..p {
        let nr = ar[j] * sr[j] - ai[j] * si[j] + bf16_to_f32(br[j]);
        let ni = ar[j] * si[j] + ai[j] * sr[j] + bf16_to_f32(bi[j]);
        sr[j] = nr;
        si[j] = ni;
        br[j] = f32_to_bf16(nr);
        bi[j] = f32_to_bf16(ni);
    }
}

/// bf16 twin of [`fixup_row`]: the carry advances in f32; the emitted
/// rows widen, take the addition in f32, and narrow back.
#[inline]
pub(crate) fn fixup_row_bf16(
    ar: &[f32],
    ai: &[f32],
    cr: &mut [f32],
    ci: &mut [f32],
    xr: &mut [Bf16],
    xi: &mut [Bf16],
) {
    let p = cr.len();
    let pb = p - p % LANES;
    let mut j = 0;
    while j < pb {
        let (av, bv) = (load(ar, j), load(ai, j));
        let (crv, civ) = (load(cr, j), load(ci, j));
        let mut nr = [0.0f32; LANES];
        let mut ni = [0.0f32; LANES];
        for t in 0..LANES {
            nr[t] = crv[t] * av[t] - civ[t] * bv[t];
            ni[t] = crv[t] * bv[t] + civ[t] * av[t];
        }
        store(cr, j, &nr);
        store(ci, j, &ni);
        let (xrv, xiv) = (load16(xr, j), load16(xi, j));
        let mut sxr = [0.0f32; LANES];
        let mut sxi = [0.0f32; LANES];
        for t in 0..LANES {
            sxr[t] = xrv[t] + nr[t];
            sxi[t] = xiv[t] + ni[t];
        }
        store16(xr, j, &sxr);
        store16(xi, j, &sxi);
        j += LANES;
    }
    for j in pb..p {
        let nr = cr[j] * ar[j] - ci[j] * ai[j];
        let ni = cr[j] * ai[j] + ci[j] * ar[j];
        cr[j] = nr;
        ci[j] = ni;
        xr[j] = f32_to_bf16(bf16_to_f32(xr[j]) + nr);
        xi[j] = f32_to_bf16(bf16_to_f32(xi[j]) + ni);
    }
}

/// bf16 twin of [`project_row`]: widen each stored state element once,
/// then the identical blocked f64 reduction.
pub(crate) fn project_row_bf16(
    ct: &[C64],
    xr: &[Bf16],
    xi: &[Bf16],
    y: &mut [f32],
    h: usize,
    p2: usize,
) {
    let hb = h - h % PROJ_LANES;
    let mut r = 0;
    while r < hb {
        let mut acc = [0.0f64; PROJ_LANES];
        for c in 0..p2 {
            let (xrc, xic) = (bf16_to_f32(xr[c]) as f64, bf16_to_f32(xi[c]) as f64);
            for t in 0..PROJ_LANES {
                let cv = ct[(r + t) * p2 + c];
                acc[t] += cv.re * xrc - cv.im * xic;
            }
        }
        for t in 0..PROJ_LANES {
            y[r + t] += 2.0 * acc[t] as f32;
        }
        r += PROJ_LANES;
    }
    for r in hb..h {
        let mut acc = 0.0f64;
        for c in 0..p2 {
            let cv = ct[r * p2 + c];
            acc += cv.re * bf16_to_f32(xr[c]) as f64 - cv.im * bf16_to_f32(xi[c]) as f64;
        }
        y[r] += 2.0 * acc as f32;
    }
}

// s5:hot-end

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny deterministic generator (no external deps; value range keeps
    /// products finite).
    struct Lcg(u64);
    impl Lcg {
        fn f32(&mut self) -> f32 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((self.0 >> 33) as i32 as f64 / i32::MAX as f64) as f32
        }
        fn vec(&mut self, n: usize) -> Vec<f32> {
            (0..n).map(|_| self.f32()).collect()
        }
    }

    const PS: [usize; 6] = [1, 3, 7, 8, 17, 40];

    /// The lane-blocked kernels equal their scalar references **bit for
    /// bit** — the blocks group independent elements and never change an
    /// op order, so this is exact equality, not a tolerance.
    #[test]
    fn lane_blocks_match_scalar_bit_for_bit() {
        let mut g = Lcg(7);
        for &p in &PS {
            let rows = 5;
            let (ar, ai) = (g.vec(p), g.vec(p));
            let (fr, fi) = (g.vec(p), g.vec(p));

            // scale_rows
            let (mut br, mut bi) = (g.vec(rows * p), g.vec(rows * p));
            let (mut br2, mut bi2) = (br.clone(), bi.clone());
            scale_rows(&mut br, &mut bi, &fr, &fi, rows, p);
            for k in 0..rows {
                for j in 0..p {
                    let (b_r, b_i) = (br2[k * p + j], bi2[k * p + j]);
                    br2[k * p + j] = fr[j] * b_r - fi[j] * b_i;
                    bi2[k * p + j] = fr[j] * b_i + fi[j] * b_r;
                }
            }
            assert_eq!(br, br2, "scale re p={p}");
            assert_eq!(bi, bi2, "scale im p={p}");

            // scan_row_step
            let (pr, pi) = (g.vec(p), g.vec(p));
            let (mut cr, mut ci) = (g.vec(p), g.vec(p));
            let (mut cr2, mut ci2) = (cr.clone(), ci.clone());
            scan_row_step(&ar, &ai, &pr, &pi, &mut cr, &mut ci);
            for j in 0..p {
                let nr = ar[j] * pr[j] - ai[j] * pi[j] + cr2[j];
                let ni = ar[j] * pi[j] + ai[j] * pr[j] + ci2[j];
                cr2[j] = nr;
                ci2[j] = ni;
            }
            assert_eq!(cr, cr2, "step re p={p}");
            assert_eq!(ci, ci2, "step im p={p}");

            // scan_row_resume
            let (mut sr, mut si) = (g.vec(p), g.vec(p));
            let (mut rr, mut ri) = (g.vec(p), g.vec(p));
            let (mut sr2, mut si2) = (sr.clone(), si.clone());
            let (mut rr2, mut ri2) = (rr.clone(), ri.clone());
            scan_row_resume(&ar, &ai, &mut sr, &mut si, &mut rr, &mut ri);
            for j in 0..p {
                let nr = ar[j] * sr2[j] - ai[j] * si2[j] + rr2[j];
                let ni = ar[j] * si2[j] + ai[j] * sr2[j] + ri2[j];
                sr2[j] = nr;
                si2[j] = ni;
                rr2[j] = nr;
                ri2[j] = ni;
            }
            assert_eq!((sr, si), (sr2, si2), "resume state p={p}");
            assert_eq!((rr, ri), (rr2, ri2), "resume row p={p}");

            // combine_row
            let (lr, li) = (g.vec(p), g.vec(p));
            let (mut str_, mut sti) = (g.vec(p), g.vec(p));
            let (mut str2, mut sti2) = (str_.clone(), sti.clone());
            combine_row(&ar, &ai, &lr, &li, &mut str_, &mut sti);
            for j in 0..p {
                let nr = ar[j] * str2[j] - ai[j] * sti2[j] + lr[j];
                let ni = ar[j] * sti2[j] + ai[j] * str2[j] + li[j];
                str2[j] = nr;
                sti2[j] = ni;
            }
            assert_eq!((str_, sti), (str2, sti2), "combine p={p}");

            // cmul_row
            let (mut mr, mut mi) = (g.vec(p), g.vec(p));
            let (mut mr2, mut mi2) = (mr.clone(), mi.clone());
            cmul_row(&ar, &ai, &mut mr, &mut mi);
            for j in 0..p {
                let nr = ar[j] * mr2[j] - ai[j] * mi2[j];
                let ni = ar[j] * mi2[j] + ai[j] * mr2[j];
                mr2[j] = nr;
                mi2[j] = ni;
            }
            assert_eq!((mr, mi), (mr2, mi2), "cmul p={p}");

            // fixup_row
            let (mut fcr, mut fci) = (g.vec(p), g.vec(p));
            let (mut xr, mut xi) = (g.vec(p), g.vec(p));
            let (mut fcr2, mut fci2) = (fcr.clone(), fci.clone());
            let (mut xr2, mut xi2) = (xr.clone(), xi.clone());
            fixup_row(&ar, &ai, &mut fcr, &mut fci, &mut xr, &mut xi);
            for j in 0..p {
                let nr = fcr2[j] * ar[j] - fci2[j] * ai[j];
                let ni = fcr2[j] * ai[j] + fci2[j] * ar[j];
                fcr2[j] = nr;
                fci2[j] = ni;
                xr2[j] += nr;
                xi2[j] += ni;
            }
            assert_eq!((fcr, fci), (fcr2, fci2), "fixup carry p={p}");
            assert_eq!((xr, xi), (xr2, xi2), "fixup x p={p}");
        }
    }

    /// Projection block: private per-channel f64 reductions in scalar
    /// order — exact equality for every (h, p2) block/tail split.
    #[test]
    fn project_row_matches_scalar_bit_for_bit() {
        let mut g = Lcg(11);
        for &h in &[1usize, 3, 4, 5, 11, 16] {
            for &p2 in &[1usize, 2, 8, 33] {
                let ct: Vec<C64> =
                    (0..h * p2).map(|_| C64::new(g.f32() as f64, g.f32() as f64)).collect();
                let (xr, xi) = (g.vec(p2), g.vec(p2));
                let mut y = g.vec(h);
                let mut y2 = y.clone();
                project_row(&ct, &xr, &xi, &mut y, h, p2);
                for r in 0..h {
                    let mut acc = 0.0f64;
                    for c in 0..p2 {
                        let cv = ct[r * p2 + c];
                        acc += cv.re * xr[c] as f64 - cv.im * xi[c] as f64;
                    }
                    y2[r] += 2.0 * acc as f32;
                }
                assert_eq!(y, y2, "h={h} p2={p2}");
            }
        }
    }

    /// The bf16 lane kernels equal their widen/narrow scalar references
    /// **bit for bit** — same contract as the f32 blocks, with the
    /// round-to-nearest-even narrowing placed identically.
    #[test]
    fn bf16_lane_blocks_match_scalar_bit_for_bit() {
        let mut g = Lcg(19);
        let narrow = |v: Vec<f32>| -> Vec<Bf16> { v.iter().map(|&x| f32_to_bf16(x)).collect() };
        for &p in &PS {
            let rows = 5;
            let (ar, ai) = (g.vec(p), g.vec(p));
            let (fr, fi) = (g.vec(p), g.vec(p));

            // scale_rows_bf16
            let (mut br, mut bi) = (narrow(g.vec(rows * p)), narrow(g.vec(rows * p)));
            let (mut br2, mut bi2) = (br.clone(), bi.clone());
            scale_rows_bf16(&mut br, &mut bi, &fr, &fi, rows, p);
            for k in 0..rows {
                for j in 0..p {
                    let (b_r, b_i) = (bf16_to_f32(br2[k * p + j]), bf16_to_f32(bi2[k * p + j]));
                    br2[k * p + j] = f32_to_bf16(fr[j] * b_r - fi[j] * b_i);
                    bi2[k * p + j] = f32_to_bf16(fr[j] * b_i + fi[j] * b_r);
                }
            }
            assert_eq!(br, br2, "bf16 scale re p={p}");
            assert_eq!(bi, bi2, "bf16 scale im p={p}");

            // scan_row_resume_bf16 — state stays f32, row narrows
            let (mut sr, mut si) = (g.vec(p), g.vec(p));
            let (mut rr, mut ri) = (narrow(g.vec(p)), narrow(g.vec(p)));
            let (mut sr2, mut si2) = (sr.clone(), si.clone());
            let (mut rr2, mut ri2) = (rr.clone(), ri.clone());
            scan_row_resume_bf16(&ar, &ai, &mut sr, &mut si, &mut rr, &mut ri);
            for j in 0..p {
                let nr = ar[j] * sr2[j] - ai[j] * si2[j] + bf16_to_f32(rr2[j]);
                let ni = ar[j] * si2[j] + ai[j] * sr2[j] + bf16_to_f32(ri2[j]);
                sr2[j] = nr;
                si2[j] = ni;
                rr2[j] = f32_to_bf16(nr);
                ri2[j] = f32_to_bf16(ni);
            }
            assert_eq!((sr, si), (sr2, si2), "bf16 resume state p={p}");
            assert_eq!((rr, ri), (rr2, ri2), "bf16 resume row p={p}");

            // fixup_row_bf16 — carry stays f32, rows widen-add-narrow
            let (mut fcr, mut fci) = (g.vec(p), g.vec(p));
            let (mut xr, mut xi) = (narrow(g.vec(p)), narrow(g.vec(p)));
            let (mut fcr2, mut fci2) = (fcr.clone(), fci.clone());
            let (mut xr2, mut xi2) = (xr.clone(), xi.clone());
            fixup_row_bf16(&ar, &ai, &mut fcr, &mut fci, &mut xr, &mut xi);
            for j in 0..p {
                let nr = fcr2[j] * ar[j] - fci2[j] * ai[j];
                let ni = fcr2[j] * ai[j] + fci2[j] * ar[j];
                fcr2[j] = nr;
                fci2[j] = ni;
                xr2[j] = f32_to_bf16(bf16_to_f32(xr2[j]) + nr);
                xi2[j] = f32_to_bf16(bf16_to_f32(xi2[j]) + ni);
            }
            assert_eq!((fcr, fci), (fcr2, fci2), "bf16 fixup carry p={p}");
            assert_eq!((xr, xi), (xr2, xi2), "bf16 fixup x p={p}");
        }
    }

    /// bf16 projection block vs the scalar widen-first reference — exact
    /// equality (widening is exact, the f64 reduction order is shared).
    #[test]
    fn bf16_project_row_matches_scalar_bit_for_bit() {
        let mut g = Lcg(23);
        for &h in &[1usize, 3, 4, 5, 11, 16] {
            for &p2 in &[1usize, 2, 8, 33] {
                let ct: Vec<C64> =
                    (0..h * p2).map(|_| C64::new(g.f32() as f64, g.f32() as f64)).collect();
                let xr: Vec<Bf16> = g.vec(p2).iter().map(|&x| f32_to_bf16(x)).collect();
                let xi: Vec<Bf16> = g.vec(p2).iter().map(|&x| f32_to_bf16(x)).collect();
                let mut y = g.vec(h);
                let mut y2 = y.clone();
                project_row_bf16(&ct, &xr, &xi, &mut y, h, p2);
                for r in 0..h {
                    let mut acc = 0.0f64;
                    for c in 0..p2 {
                        let cv = ct[r * p2 + c];
                        let (wr, wi) = (bf16_to_f32(xr[c]) as f64, bf16_to_f32(xi[c]) as f64);
                        acc += cv.re * wr - cv.im * wi;
                    }
                    y2[r] += 2.0 * acc as f32;
                }
                assert_eq!(y, y2, "bf16 h={h} p2={p2}");
            }
        }
    }

    /// 64k resumed steps of a running sum (ā = 1, constant drive): the
    /// drift-prone long-L shape. The lane path must track the scalar path
    /// exactly at every step — accumulated f32 rounding and all.
    #[test]
    fn long_l_running_sum_stays_bit_exact() {
        let p = 12; // one full block + tail
        let ar = vec![1.0f32; p];
        let ai = vec![0.0f32; p];
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        let (mut sr2, mut si2) = (sr.clone(), si.clone());
        let mut g = Lcg(3);
        for _ in 0..65536 {
            let (mut br, mut bi) = (g.vec(p), g.vec(p));
            for v in br.iter_mut().chain(bi.iter_mut()) {
                *v *= 1e-3;
            }
            let (mut br2, mut bi2) = (br.clone(), bi.clone());
            scan_row_resume(&ar, &ai, &mut sr, &mut si, &mut br, &mut bi);
            for j in 0..p {
                let nr = ar[j] * sr2[j] - ai[j] * si2[j] + br2[j];
                let ni = ar[j] * si2[j] + ai[j] * sr2[j] + bi2[j];
                sr2[j] = nr;
                si2[j] = ni;
                br2[j] = nr;
                bi2[j] = ni;
            }
            assert_eq!((&sr, &si), (&sr2, &si2));
        }
        assert!(sr.iter().any(|v| v.abs() > 1.0), "the sum should have accumulated");
    }
}
