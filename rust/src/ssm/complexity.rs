//! Operation-count models for Proposition 1 (paper §3.4, Appendix C.1).
//!
//! These closed-form counts are the *analytical* half of the complexity
//! reproduction; `bench_scan_scaling` and `bench_table4_runtime` provide the
//! measured half. The claims under test:
//!
//! * S4 offline (conv):   O(H²L + H·L·log L) work, O(log H + log L) depth;
//! * S5 offline (scan):   O(H·P·L + P·L) work,    O(log P + log L) depth;
//! * online step:         S4 O(H² + H·N) vs S5 O(P·H + P);
//! * dense-A MIMO scan:   O(P³) per combine — the §2.2 blowup that
//!   diagonalization removes.

/// Work (flop-ish op count) of one S4 layer applied offline via FFT conv.
pub fn s4_conv_work(h: usize, _n: usize, l: usize) -> usize {
    // kernel application: H FFT pairs of length 2L (≈ 5·2L·log2(2L) real ops
    // each for fwd+inv+pointwise) + H²L mixing.
    let l2 = (2 * l).max(2);
    let fft_ops = 5 * l2 * l2.ilog2() as usize;
    h * fft_ops + h * h * l
}

/// Work of one S5 layer applied offline via diagonal parallel scan.
pub fn s5_scan_work(h: usize, p: usize, l: usize) -> usize {
    // B̄u and C̃x matmuls: 2·P·H·L complex mults (≈ 8 real ops each) +
    // work-efficient scan: ≈ 2·P·L complex fma.
    8 * (2 * p * h * l) + 8 * (2 * p * l)
}

/// Work of the dense-A MIMO parallel scan (the strawman §2.2 rules out):
/// each of the O(L) combines multiplies P×P matrices.
pub fn dense_scan_work(p: usize, l: usize) -> usize {
    2 * l * p * p * p
}

/// Per-step online work: S4 (DPLR matvec + mixing).
pub fn s4_online_step(h: usize, n: usize) -> usize {
    h * n + h * h
}

/// Per-step online work: S5 (diagonal matvec + in/out projections).
pub fn s5_online_step(h: usize, p: usize) -> usize {
    p + 2 * p * h
}

/// Parallel depth (critical path length in op units) of the offline modes,
/// assuming unbounded processors.
pub fn s4_parallel_depth(h: usize, l: usize) -> usize {
    (h.max(2).ilog2() + (2 * l).max(2).ilog2()) as usize
}

pub fn s5_parallel_depth(p: usize, l: usize) -> usize {
    (p.max(2).ilog2() + l.max(2).ilog2()) as usize
}

/// Memory footprint (f32 words) of the offline modes.
pub fn s4_conv_space(h: usize, l: usize) -> usize {
    // H FFT buffers of 2L complex + activations
    h * 2 * l * 2 + h * l
}

pub fn s5_scan_space(p: usize, l: usize, h: usize) -> usize {
    // scan state (L,P) complex + activations
    2 * p * l + h * l
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop1_same_order_when_p_is_order_h() {
        // With P = H, the work ratio S5/S4 must stay bounded (same order)
        // across two decades of L.
        let h = 128;
        for l in [1024usize, 4096, 16384, 65536] {
            let r = s5_scan_work(h, h, l) as f64 / s4_conv_work(h, h, l) as f64;
            assert!(r > 0.05 && r < 20.0, "L={l}: ratio {r}");
        }
    }

    #[test]
    fn s5_wins_asymptotically_in_l() {
        // S4 carries an extra log L factor: the ratio S4/S5 must grow with L
        // once H is small relative to log L.
        let h = 16;
        let r1 = s4_conv_work(h, 64, 1 << 10) as f64 / s5_scan_work(h, 64, 1 << 10) as f64;
        let r2 = s4_conv_work(h, 64, 1 << 20) as f64 / s5_scan_work(h, 64, 1 << 20) as f64;
        assert!(r2 > r1, "log L advantage missing: {r1} vs {r2}");
    }

    #[test]
    fn dense_scan_is_cubically_worse() {
        // compare against the *scan* term alone (16·P·L): the dense combine
        // pays P³ per element vs P for the diagonal form (§2.2).
        let (p, l) = (64, 4096);
        let diag_scan = 8 * 2 * p * l;
        let ratio = dense_scan_work(p, l) as f64 / diag_scan as f64;
        assert!(ratio > 250.0, "diagonalization advantage missing: {ratio}");
        // and the full S5 layer (including projections) still wins big
        let full = dense_scan_work(p, l) as f64 / s5_scan_work(64, p, l) as f64;
        assert!(full > 5.0, "{full}");
    }

    #[test]
    fn online_steps_match_at_p_equals_h_and_n_equals_h() {
        let h = 64;
        let s4 = s4_online_step(h, h);
        let s5 = s5_online_step(h, h);
        let ratio = s4 as f64 / s5 as f64;
        assert!((0.2..5.0).contains(&ratio), "{ratio}");
    }

    #[test]
    fn parallel_depths_are_logarithmic() {
        assert_eq!(s5_parallel_depth(64, 16384), 6 + 14);
        assert!(s4_parallel_depth(64, 16384) >= s5_parallel_depth(64, 16384));
    }

    #[test]
    fn space_same_order_at_p_equals_h() {
        let (h, l) = (128, 16384);
        let r = s5_scan_space(h, l, h) as f64 / s4_conv_space(h, l) as f64;
        assert!(r > 0.05 && r < 5.0, "{r}");
    }
}
