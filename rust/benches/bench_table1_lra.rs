//! Paper Table 1/7: the LRA-style suite.
//!
//! Trains the S5 classifier on all six synthetic LRA-analogue tasks through
//! the real train-step artifacts and reports held-out accuracy next to the
//! paper's numbers. Absolute values are not comparable (synthetic data,
//! minutes-scale budgets vs the paper's GPU-days), but the qualitative
//! shape is asserted: every task trains above chance within the budget —
//! including the Path-X analogue, the paper's headline claim.
//!
//! Budget knobs: S5_BENCH_STEPS (default 40), S5_BENCH_QUICK=1 (8 steps).

use s5::coordinator::{TrainConfig, Trainer};
use s5::runtime::Client;
use s5::util::Table;
use std::path::Path;

struct Row {
    task: &'static str,
    preset: &'static str,
    paper_s5: f64,
    chance: f64,
}

const ROWS: &[Row] = &[
    Row { task: "ListOps", preset: "listops", paper_s5: 62.15, chance: 0.10 },
    Row { task: "Text", preset: "text", paper_s5: 89.31, chance: 0.50 },
    Row { task: "Retrieval", preset: "retrieval", paper_s5: 91.40, chance: 0.50 },
    Row { task: "Image", preset: "image", paper_s5: 88.00, chance: 0.10 },
    Row { task: "Pathfinder", preset: "pathfinder", paper_s5: 95.33, chance: 0.50 },
    Row { task: "Path-X", preset: "pathx", paper_s5: 98.58, chance: 0.50 },
];

fn steps() -> usize {
    if let Ok(v) = std::env::var("S5_BENCH_STEPS") {
        return v.parse().unwrap_or(40);
    }
    if s5::bench::quick_mode() {
        8
    } else {
        40
    }
}

fn main() {
    let steps = steps();
    println!("# Table 1 reproduction — LRA-style suite ({steps} steps/task)\n");
    let client = Client::cpu().expect("pjrt client");
    let mut table = Table::new(&[
        "Task", "L", "paper S5 %", "ours % (tiny budget)", "chance %", "> chance",
    ]);
    let mut above_chance = 0;
    let mut ran = 0;
    for row in ROWS {
        if !Path::new("artifacts")
            .join(format!("{}_train.hlo.txt", row.preset))
            .exists()
        {
            eprintln!("skipping {} (artifact missing)", row.preset);
            continue;
        }
        let mut cfg = TrainConfig::for_preset(row.preset);
        cfg.steps = steps;
        cfg.train_pool = 192;
        cfg.eval_pool = 64;
        cfg.eval_every = 0;
        cfg.warmup_steps = steps / 10 + 1;
        let t0 = std::time::Instant::now();
        let mut trainer = Trainer::new(&client, cfg).expect("trainer");
        for _ in 0..steps {
            trainer.train_step().expect("step");
        }
        let (_, acc) = trainer.evaluate().expect("eval");
        eprintln!(
            "  {}: acc {:.1}% in {:.0}s",
            row.task,
            acc * 100.0,
            t0.elapsed().as_secs_f64()
        );
        let seq_len = match row.preset {
            "listops" => 512,
            "text" => 1024,
            "retrieval" => 512,
            "image" | "pathfinder" => 1024,
            _ => 4096,
        };
        let ok = acc > row.chance + 0.02;
        if ok {
            above_chance += 1;
        }
        ran += 1;
        table.row(&[
            row.task.to_string(),
            seq_len.to_string(),
            format!("{:.2}", row.paper_s5),
            format!("{:.1}", acc * 100.0),
            format!("{:.0}", row.chance * 100.0),
            if ok { "✓".into() } else { "✗".into() },
        ]);
    }
    println!("{}", table.render());
    println!("{above_chance}/{ran} tasks above chance within the tiny budget");
    println!("(paper: S5 LRA average 87.46%, best-in-class on Path-X at 98.58%)");
}
