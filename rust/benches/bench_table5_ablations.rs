//! Paper Table 5: S5 architecture ablations on a pixel-level task —
//! (a) constrained P=N, J=1, scalar Δ (the Proposition-2 regime),
//! (b) same but vector Δ ∈ ℝ^P (§D.5),
//! (c) the unconstrained default (P free, block-diagonal J>1 init).
//!
//! The paper's finding: (a) < (b) < (c). Each variant is a separate AOT
//! artifact trained through PJRT on the same data stream/seed.
//!
//! Run: `cargo bench --bench bench_table5_ablations`

use s5::coordinator::{TrainConfig, Trainer};
use s5::runtime::Client;
use s5::util::Table;
use std::path::Path;

fn main() {
    let steps: usize = std::env::var("S5_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if s5::bench::quick_mode() { 8 } else { 60 });

    let variants = [
        ("S5 (P=N, J=1, Δ∈ℝ)", "abl5_pn_scalar", "57.20 (ListOps col)"),
        ("S5 (P=N, J=1, Δ∈ℝ^N)", "abl5_pn_vector", "58.65"),
        ("S5 (P free, J=4, Δ∈ℝ^P)", "smnist", "62.15"),
    ];

    println!("# Table 5 reproduction — S5 ablations ({steps} steps each, sMNIST task)\n");
    let client = Client::cpu().expect("client");
    let mut table = Table::new(&["variant", "paper trend", "ours: loss", "ours: acc %"]);
    let mut results = Vec::new();
    for (name, preset, paper) in variants {
        if !Path::new("artifacts")
            .join(format!("{preset}_train.hlo.txt"))
            .exists()
        {
            eprintln!("skipping {preset} (artifact missing)");
            continue;
        }
        let mut cfg = TrainConfig::for_preset(preset);
        cfg.steps = steps;
        cfg.train_pool = 192;
        cfg.eval_pool = 64;
        cfg.eval_every = 0;
        cfg.seed = 7;
        let mut trainer = Trainer::new(&client, cfg).expect("trainer");
        for _ in 0..steps {
            trainer.train_step().expect("step");
        }
        let (loss, acc) = trainer.evaluate().expect("eval");
        eprintln!("  {name}: loss={loss:.4} acc={:.1}%", acc * 100.0);
        results.push((name, loss, acc));
        table.row(&[
            name.to_string(),
            paper.to_string(),
            format!("{loss:.4}"),
            format!("{:.1}", acc * 100.0),
        ]);
    }
    println!("{}", table.render());
    println!("paper shape: scalar-Δ constrained < vector-Δ constrained < unconstrained");
    if results.len() == 3 {
        let trend_ok = results[2].2 >= results[0].2 - 0.05;
        println!(
            "unconstrained ≥ scalar-Δ constrained (within noise): {}",
            if trend_ok { "✓" } else { "✗ (budget too small)" }
        );
    }
}
