//! Paper Table 4: runtime comparison of S4 (conv), S4D and S5 across
//! sequence lengths, reported as speed multiples of the S4D baseline —
//! exactly the paper's format.
//!
//! Subjects are the pure-Rust reference implementations (we control every
//! allocation, so this measures the algorithms, not framework overhead):
//!
//! * S4D conv mode — Vandermonde kernel + FFT convolution, O(H·L·log L);
//! * S4D recurrent — the online mode, O(H·N) per step;
//! * S4 scan-bank  — the block-diagonal H·N-state scan §2.3 warns about;
//! * S5 scan (seq) — the diagonal MIMO scan at P (single-thread);
//! * S5 scan (par) — the same with the multi-threaded Blelloch scan.
//!
//! Run: `cargo bench --bench bench_table4_runtime`
//! (S5_BENCH_QUICK=1 shrinks workloads for smoke runs.)

#![allow(deprecated)] // legacy positional wrappers are the subjects/oracles here

use s5::bench::{measure, quick_mode, RelativeReport};
use s5::rng::Rng;
use s5::ssm::s4::S4DLayer;
use s5::ssm::s5::{S5Config, S5Layer};
use s5::util::human_bytes;

fn main() {
    // paper Table 4 dimensions, scaled: H features, N=64 per S4 SSM, S5 at
    // P=2N (the "P free" row) — lengths from ListOps/Text/Path-X.
    let lengths: &[usize] = if quick_mode() {
        &[256, 1024]
    } else {
        &[2048, 4096, 16384]
    };
    let h = 32;
    let n = 64;
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);

    println!("# Table 4 reproduction — runtime vs S4D baseline");
    println!("H={h}, S4 SSM state N={n}, S5 latent P={n} (=N), threads={threads}\n");

    let mut rng = Rng::new(0xBE4C);
    let s4d = S4DLayer::init(h, n, &mut rng);
    let s5cfg = S5Config { h, p: n, j: 1, ..Default::default() };
    let s5 = S5Layer::init(&s5cfg, &mut rng);

    for &l in lengths {
        let u = Rng::new(l as u64).normal_vec_f32(l * h);
        let mut report = RelativeReport::new(&format!("L = {l}"), "S4D conv");

        let st = measure("s4d conv", || {
            std::hint::black_box(s4d.apply_conv_ssm(&u, l));
        });
        report.add("S4D conv", st);

        let st = measure("s4d recurrent", || {
            std::hint::black_box(s4d.apply_recurrent_ssm(&u, l));
        });
        report.add("S4D recurrent", st);

        // the H·N-state bank scan the paper rules out for S4 (§2.3)
        let st = measure("s4 scan-bank", || {
            std::hint::black_box(s4d.apply_scan_ssm(&u, l, threads));
        });
        report.add("S4 scan-bank (HN state)", st);

        let st = measure("s5 scan seq", || {
            std::hint::black_box(s5.apply_ssm(&u, l, 1.0, None, 1));
        });
        report.add("S5 scan (1 thread)", st);

        let st = measure("s5 scan par", || {
            std::hint::black_box(s5.apply_ssm(&u, l, 1.0, None, threads));
        });
        report.add(&format!("S5 scan ({threads} threads)"), st);

        println!("{}", report.render());
        // memory accounting (paper's third block)
        let s4_mem = s5::ssm::complexity::s4_conv_space(h, l) * 4;
        let s5_mem = s5::ssm::complexity::s5_scan_space(n / 2, l, h) * 4;
        println!(
            "memory (model): S4D {} vs S5 {} ({:.2}x)\n",
            human_bytes(s4_mem),
            human_bytes(s5_mem),
            s5_mem as f64 / s4_mem as f64
        );
    }

    println!("paper shape: S5 ≈ S4D at short L, pulling ahead as L grows");
    println!("(paper Table 4: 1.9-4.7x at L=16,384 on GPU; crossover shape is the claim)");
}
