//! Serving benchmark: throughput/latency of the L3 inference server as a
//! function of the dynamic-batching window. Not a paper table — this
//! validates that the coordinator itself is not the bottleneck (the L3
//! perf target in DESIGN.md §6).
//!
//! Run: `cargo bench --bench bench_server`

use s5::bench::quick_mode;
use s5::coordinator::server::{InferenceServer, ServerConfig};
use s5::data::make_task;
use s5::rng::Rng;
use s5::util::{Stats, Table};
use std::path::Path;
use std::time::Duration;

fn main() {
    if !Path::new("artifacts/smnist_fwd.hlo.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts`");
        return;
    }
    let n_requests = if quick_mode() { 24 } else { 96 };
    let clients = 12;
    let task = make_task("smnist").unwrap();

    println!("# Inference server: batching-window sweep ({n_requests} requests, {clients} clients)\n");
    let mut table = Table::new(&[
        "max_wait", "req/s", "p50 latency", "p95 latency", "mean batch fill",
    ]);
    for wait_ms in [0u64, 1, 5, 20] {
        let server = InferenceServer::start(
            Path::new("artifacts"),
            "smnist",
            None,
            ServerConfig { max_wait: Duration::from_millis(wait_ms) },
        )
        .expect("server");
        let handle = server.handle();
        let t0 = std::time::Instant::now();
        let lat: Vec<f64> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..clients)
                .map(|c| {
                    let h = handle.clone();
                    let task = &task;
                    let per = n_requests / clients;
                    s.spawn(move || {
                        let mut rng = Rng::new(c as u64);
                        (0..per)
                            .map(|_| {
                                let ex = task.sample(&mut rng);
                                h.infer(ex.x).expect("infer").total_secs
                            })
                            .collect::<Vec<f64>>()
                    })
                })
                .collect();
            joins.into_iter().flat_map(|j| j.join().unwrap()).collect()
        });
        let wall = t0.elapsed().as_secs_f64();
        let st = Stats::from(&lat);
        table.row(&[
            format!("{wait_ms}ms"),
            format!("{:.1}", lat.len() as f64 / wall),
            format!("{:.1}ms", st.p50 * 1e3),
            format!("{:.1}ms", st.p95 * 1e3),
            format!("{:.2}", server.stats.mean_batch_fill()),
        ]);
    }
    println!("{}", table.render());
    println!("expected shape: larger windows → higher fill & throughput, higher p50");
}
