//! Serving benchmark: throughput/latency of the L3 inference server as a
//! function of the dynamic-batching window and batch cap. Not a paper
//! table — this validates that the coordinator itself is not the
//! bottleneck (the L3 perf target in DESIGN.md §6).
//!
//! The native batched engine runs unconditionally (no artifacts needed);
//! the PJRT sweep runs when the crate is built with the `pjrt` feature and
//! `artifacts/` exists.
//!
//! Run: `cargo bench --bench bench_server`

use s5::bench::quick_mode;
use s5::coordinator::server::{NativeInferenceServer, RunningServer, ServerConfig};
use s5::rng::Rng;
use s5::ssm::s5::{S5Config, S5Model};
use s5::util::{Stats, Table};
use std::time::Duration;

/// Fire `n_requests` across `clients` threads; returns latencies.
fn drive(server: &RunningServer, l: usize, d_in: usize, n_requests: usize, clients: usize) -> Vec<f64> {
    let handle = server.handle();
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..clients)
            .map(|c| {
                let h = handle.clone();
                let per = n_requests / clients;
                s.spawn(move || {
                    let mut rng = Rng::new(c as u64);
                    (0..per)
                        .map(|_| {
                            let x = rng.normal_vec_f32(l * d_in);
                            h.infer(x).expect("infer").total_secs
                        })
                        .collect::<Vec<f64>>()
                })
            })
            .collect();
        joins.into_iter().flat_map(|j| j.join().unwrap()).collect()
    })
}

fn main() {
    let quick = quick_mode();
    let n_requests = if quick { 24 } else { 96 };
    let clients = 12;
    let (l, d_in, classes) = (if quick { 64 } else { 256 }, 4usize, 10usize);

    println!(
        "# Native inference server: batching-window sweep ({n_requests} requests, {clients} clients, L={l})\n"
    );
    let cfg_model = S5Config { h: 32, p: 32, j: 1, ..Default::default() };
    let model = S5Model::init(d_in, classes, 2, &cfg_model, &mut Rng::new(3));

    let mut table = Table::new(&[
        "max_wait", "max_batch", "req/s", "p50 latency", "p95 latency", "mean batch fill",
    ]);
    for (wait_ms, max_batch) in [(0u64, 16usize), (1, 16), (5, 16), (20, 16), (5, 1), (5, 4)] {
        let server = RunningServer::Native(NativeInferenceServer::start(
            model.clone(),
            l,
            ServerConfig {
                max_wait: Duration::from_millis(wait_ms),
                max_batch,
                threads: 0, // auto
                ..ServerConfig::default()
            },
        ));
        let t0 = std::time::Instant::now();
        let lat = drive(&server, l, d_in, n_requests, clients);
        let wall = t0.elapsed().as_secs_f64();
        let st = Stats::from(&lat);
        table.row(&[
            format!("{wait_ms}ms"),
            max_batch.to_string(),
            format!("{:.1}", lat.len() as f64 / wall),
            format!("{:.1}ms", st.p50 * 1e3),
            format!("{:.1}ms", st.p95 * 1e3),
            format!("{:.2}", server.stats().mean_batch_fill()),
        ]);
    }
    println!("{}", table.render());
    println!("expected shape: larger windows → higher fill & throughput, higher p50;\nmax_batch=1 (no coalescing) is the throughput floor");

    #[cfg(feature = "pjrt")]
    pjrt_sweep(n_requests, clients);
}

#[cfg(feature = "pjrt")]
fn pjrt_sweep(n_requests: usize, clients: usize) {
    use s5::coordinator::server::InferenceServer;
    use s5::data::make_task;
    use std::path::Path;

    if !Path::new("artifacts/smnist_fwd.hlo.txt").exists() {
        eprintln!("artifacts missing — skipping PJRT sweep (run `make artifacts`)");
        return;
    }
    let task = make_task("smnist").unwrap();
    println!("\n# PJRT inference server: batching-window sweep\n");
    let mut table = Table::new(&[
        "max_wait", "req/s", "p50 latency", "p95 latency", "mean batch fill",
    ]);
    for wait_ms in [0u64, 1, 5, 20] {
        let server = InferenceServer::start(
            Path::new("artifacts"),
            "smnist",
            None,
            ServerConfig { max_wait: Duration::from_millis(wait_ms), ..Default::default() },
        )
        .expect("server");
        let handle = server.handle();
        let t0 = std::time::Instant::now();
        let lat: Vec<f64> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..clients)
                .map(|c| {
                    let h = handle.clone();
                    let task = &task;
                    let per = n_requests / clients;
                    s.spawn(move || {
                        let mut rng = Rng::new(c as u64);
                        (0..per)
                            .map(|_| {
                                let ex = task.sample(&mut rng);
                                h.infer(ex.x).expect("infer").total_secs
                            })
                            .collect::<Vec<f64>>()
                    })
                })
                .collect();
            joins.into_iter().flat_map(|j| j.join().unwrap()).collect()
        });
        let wall = t0.elapsed().as_secs_f64();
        let st = Stats::from(&lat);
        table.row(&[
            format!("{wait_ms}ms"),
            format!("{:.1}", lat.len() as f64 / wall),
            format!("{:.1}ms", st.p50 * 1e3),
            format!("{:.1}ms", st.p95 * 1e3),
            format!("{:.2}", server.stats.mean_batch_fill()),
        ]);
    }
    println!("{}", table.render());
}
