//! Proposition 1 / §2.2 / Appendix H: parallel-scan scaling measurements.
//!
//! Four claims under measurement:
//!  1. the multi-threaded Blelloch scan speeds up with cores at long L
//!     (work-efficient: total ops stay O(P·L));
//!  2. the dense-A scan is catastrophically more expensive than the
//!     diagonal scan (why S5 diagonalizes, §2.2);
//!  3. scan cost grows linearly in L (vs the FFT path's L·log L);
//!  4. the batched engine beats a loop of single-sequence forwards
//!     (sequences/sec vs batch size × threads) — the dynamic-batching
//!     payoff the native server builds on.
//!
//! Run: `cargo bench --bench bench_scan_scaling`

#![allow(deprecated)] // legacy positional wrappers are the subjects/oracles here

use s5::bench::{fmt_secs, measure, quick_mode};
use s5::num::{C32, C64};
use s5::rng::Rng;
use s5::ssm::engine::EngineWorkspace;
use s5::ssm::s5::{S5Config, S5Model};
use s5::ssm::scan;
use s5::ssm::scan::backend_for_threads;
use s5::util::Table;

fn rand_c32(rng: &mut Rng, n: usize, scale: f32) -> Vec<C32> {
    (0..n)
        .map(|_| C32::new(rng.normal() as f32 * scale, rng.normal() as f32 * scale))
        .collect()
}

fn main() {
    let quick = quick_mode();
    let l = if quick { 8192 } else { 65536 };
    let p = 64;
    let max_threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(8);

    println!("# Parallel scan scaling (L={l}, P={p})\n");
    let mut rng = Rng::new(1);
    let a = rand_c32(&mut rng, p, 0.5);
    let b = rand_c32(&mut rng, l * p, 1.0);

    // 1. thread scaling
    let mut t = Table::new(&["threads", "time", "speedup vs 1"]);
    let base = measure("seq", || {
        std::hint::black_box(scan::scan_sequential_ti(&a, &b, l, p));
    });
    t.row(&["1 (sequential)".into(), fmt_secs(base.mean), "1.00x".into()]);
    let mut threads = 2;
    while threads <= max_threads {
        let st = measure(&format!("par{threads}"), || {
            std::hint::black_box(scan::scan_parallel_ti(&a, &b, l, p, threads));
        });
        t.row(&[
            threads.to_string(),
            fmt_secs(st.mean),
            format!("{:.2}x", base.mean / st.mean),
        ]);
        threads *= 2;
    }
    println!("## thread scaling (time-invariant diagonal scan)\n{}", t.render());

    // 2. dense vs diagonal (small L: dense is O(P²) per step sequentially)
    let ld = if quick { 512 } else { 2048 };
    let mut t = Table::new(&["state matrix", "time", "ratio"]);
    let b64: Vec<C64> = (0..ld * p).map(|_| C64::new(rng.normal(), rng.normal())).collect();
    let mut dense = vec![C64::ZERO; p * p];
    for i in 0..p {
        for j in 0..p {
            dense[i * p + j] = C64::new(rng.normal() * 0.05, rng.normal() * 0.05);
        }
    }
    let bd = rand_c32(&mut rng, ld * p, 1.0);
    let diag_st = measure("diag", || {
        std::hint::black_box(scan::scan_sequential_ti(&a, &bd, ld, p));
    });
    let dense_st = measure("dense", || {
        std::hint::black_box(scan::scan_dense_sequential(&dense, &b64, ld, p));
    });
    t.row(&["diagonal (P ops/step)".into(), fmt_secs(diag_st.mean), "1.0x".into()]);
    t.row(&[
        "dense (P² ops/step)".into(),
        fmt_secs(dense_st.mean),
        format!("{:.1}x slower", dense_st.mean / diag_st.mean),
    ]);
    println!("## dense vs diagonal at L={ld} (why S5 diagonalizes, §2.2)\n{}", t.render());

    // §Perf experiment: interleaved C32 vs planar (struct-of-arrays) layout
    {
        let ar: Vec<f32> = a.iter().map(|z| z.re).collect();
        let ai: Vec<f32> = a.iter().map(|z| z.im).collect();
        let br: Vec<f32> = b.iter().map(|z| z.re).collect();
        let bi: Vec<f32> = b.iter().map(|z| z.im).collect();
        let inter = measure("interleaved", || {
            std::hint::black_box(scan::scan_sequential_ti(&a, &b, l, p));
        });
        let planar = measure("planar", || {
            std::hint::black_box(scan::scan_sequential_ti_planar(&ar, &ai, &br, &bi, l, p));
        });
        let mut t = Table::new(&["layout", "time", "elements/s"]);
        t.row(&[
            "interleaved C32".into(),
            fmt_secs(inter.mean),
            format!("{:.0}M", (l * p) as f64 / inter.mean / 1e6),
        ]);
        t.row(&[
            "planar re/im (SoA)".into(),
            fmt_secs(planar.mean),
            format!("{:.0}M", (l * p) as f64 / planar.mean / 1e6),
        ]);
        println!(
            "## §Perf: memory layout of the scan hot loop ({:.2}x)\n{}",
            inter.mean / planar.mean,
            t.render()
        );
    }

    // 3. linear growth in L
    let mut t = Table::new(&["L", "time", "time/L (ns)"]);
    for &ll in &[4096usize, 8192, 16384, if quick { 16384 } else { 32768 }] {
        let bb = rand_c32(&mut rng, ll * p, 1.0);
        let st = measure(&format!("L{ll}"), || {
            std::hint::black_box(scan::scan_sequential_ti(&a, &bb, ll, p));
        });
        t.row(&[
            ll.to_string(),
            fmt_secs(st.mean),
            format!("{:.2}", st.mean * 1e9 / ll as f64),
        ]);
    }
    println!("## O(L) scaling (time/L should be ~constant)\n{}", t.render());

    // 4. batched engine throughput: one workspace-reusing batched forward
    // vs a loop of single-sequence forwards at the same thread budget.
    {
        let cfg = S5Config { h: 32, p: 32, j: 1, ..Default::default() };
        let model = S5Model::init(4, 10, 2, &cfg, &mut Rng::new(5));
        let lb = if quick { 96 } else { 384 };
        let mut rng = Rng::new(6);
        let mut t = Table::new(&[
            "threads", "B", "batched seq/s", "single-loop seq/s", "batched speedup",
        ]);
        let mut thread_counts = vec![2usize];
        if max_threads > 2 {
            thread_counts.push(max_threads);
        }
        for &threads in &thread_counts {
            let backend = backend_for_threads(threads);
            let mut ws = EngineWorkspace::new();
            for &bsz in &[1usize, 4, 8, 16] {
                let u = rng.normal_vec_f32(bsz * lb * 4);
                let mut out = vec![0.0f32; bsz * 10];
                // warm the workspace so the measured loop is steady-state
                model.forward_batch_into(&u, bsz, lb, 1.0, backend.as_ref(), &mut ws, &mut out);
                let st_batched = measure(&format!("batched T{threads} B{bsz}"), || {
                    model.forward_batch_into(
                        &u,
                        bsz,
                        lb,
                        1.0,
                        backend.as_ref(),
                        &mut ws,
                        &mut out,
                    );
                    std::hint::black_box(&out);
                });
                let st_loop = measure(&format!("single-loop T{threads} B{bsz}"), || {
                    for bi in 0..bsz {
                        std::hint::black_box(model.forward(
                            &u[bi * lb * 4..(bi + 1) * lb * 4],
                            lb,
                            1.0,
                            threads,
                        ));
                    }
                });
                t.row(&[
                    threads.to_string(),
                    bsz.to_string(),
                    format!("{:.1}", bsz as f64 / st_batched.mean),
                    format!("{:.1}", bsz as f64 / st_loop.mean),
                    format!("{:.2}x", st_loop.mean / st_batched.mean),
                ]);
            }
        }
        println!(
            "## batched engine vs single-sequence loop (L={lb}, H=32, 2 layers)\n{}",
            t.render()
        );
        println!("expected shape: batched speedup > 1x from B=4 up at ≥2 threads");
    }
}
