//! Proposition 1 / §2.2 / Appendix H: parallel-scan scaling measurements.
//!
//! Five claims under measurement:
//!  1. the multi-threaded Blelloch scan speeds up with cores at long L
//!     (work-efficient: total ops stay O(P·L));
//!  2. the dense-A scan is catastrophically more expensive than the
//!     diagonal scan (why S5 diagonalizes, §2.2);
//!  3. scan cost grows linearly in L (vs the FFT path's L·log L);
//!  4. the batched engine beats a loop of single-sequence forwards
//!     (sequences/sec vs batch size × threads) — the dynamic-batching
//!     payoff the native server builds on;
//!  5. the planar (SoA) `ScanBackend` kernels beat the interleaved `C32`
//!     oracle at the engine's serving shape (L=16384, P=256) — the SIMD
//!     layout win, sequential and parallel.
//!
//! Results are also snapshotted to `BENCH_scan.json` (override the path
//! with `S5_BENCH_JSON`) so the perf trajectory is recorded run-over-run.
//!
//! Run: `cargo bench --bench bench_scan_scaling`

#![allow(deprecated)] // legacy positional wrappers are the subjects/oracles here

use s5::bench::{fmt_secs, measure, quick_mode};
use s5::num::{C32, C64};
use s5::rng::Rng;
use s5::ssm::api::ForwardOptions;
use s5::ssm::engine::{EngineWorkspace, Tiling};
use s5::ssm::s5::{S5Config, S5Layer, S5Model};
use s5::ssm::scan;
use s5::ssm::scan::{
    backend_for_threads, ParallelBackend, ScanBackend, ScanExec, ScanScratch, SequentialBackend,
};
use s5::util::Table;

fn rand_c32(rng: &mut Rng, n: usize, scale: f32) -> Vec<C32> {
    (0..n)
        .map(|_| C32::new(rng.normal() as f32 * scale, rng.normal() as f32 * scale))
        .collect()
}

fn main() {
    let quick = quick_mode();
    let l = if quick { 8192 } else { 65536 };
    let p = 64;
    let max_threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(8);
    // snapshot rows: (name, mean seconds, million elements/second)
    let mut snap: Vec<(String, f64, f64)> = Vec::new();
    // scalar metrics (workspace bytes, bytes/token, …) for the snapshot
    let mut metrics: Vec<(String, f64)> = Vec::new();

    println!("# Parallel scan scaling (L={l}, P={p})\n");
    let mut rng = Rng::new(1);
    let a = rand_c32(&mut rng, p, 0.5);
    let b = rand_c32(&mut rng, l * p, 1.0);

    // 1. thread scaling
    let mut t = Table::new(&["threads", "time", "speedup vs 1"]);
    let base = measure("seq", || {
        std::hint::black_box(scan::scan_sequential_ti(&a, &b, l, p));
    });
    t.row(&["1 (sequential)".into(), fmt_secs(base.mean), "1.00x".into()]);
    snap.push(("thread_scaling/seq".into(), base.mean, (l * p) as f64 / base.mean / 1e6));
    let mut threads = 2;
    while threads <= max_threads {
        let st = measure(&format!("par{threads}"), || {
            std::hint::black_box(scan::scan_parallel_ti(&a, &b, l, p, threads));
        });
        t.row(&[
            threads.to_string(),
            fmt_secs(st.mean),
            format!("{:.2}x", base.mean / st.mean),
        ]);
        snap.push((
            format!("thread_scaling/par{threads}"),
            st.mean,
            (l * p) as f64 / st.mean / 1e6,
        ));
        threads *= 2;
    }
    println!("## thread scaling (time-invariant diagonal scan)\n{}", t.render());

    // 2. dense vs diagonal (small L: dense is O(P²) per step sequentially)
    let ld = if quick { 512 } else { 2048 };
    let mut t = Table::new(&["state matrix", "time", "ratio"]);
    let b64: Vec<C64> = (0..ld * p).map(|_| C64::new(rng.normal(), rng.normal())).collect();
    let mut dense = vec![C64::ZERO; p * p];
    for i in 0..p {
        for j in 0..p {
            dense[i * p + j] = C64::new(rng.normal() * 0.05, rng.normal() * 0.05);
        }
    }
    let bd = rand_c32(&mut rng, ld * p, 1.0);
    let diag_st = measure("diag", || {
        std::hint::black_box(scan::scan_sequential_ti(&a, &bd, ld, p));
    });
    let dense_st = measure("dense", || {
        std::hint::black_box(scan::scan_dense_sequential(&dense, &b64, ld, p));
    });
    t.row(&["diagonal (P ops/step)".into(), fmt_secs(diag_st.mean), "1.0x".into()]);
    t.row(&[
        "dense (P² ops/step)".into(),
        fmt_secs(dense_st.mean),
        format!("{:.1}x slower", dense_st.mean / diag_st.mean),
    ]);
    println!("## dense vs diagonal at L={ld} (why S5 diagonalizes, §2.2)\n{}", t.render());

    // §Perf experiment: interleaved C32 vs planar (struct-of-arrays) layout
    {
        let ar: Vec<f32> = a.iter().map(|z| z.re).collect();
        let ai: Vec<f32> = a.iter().map(|z| z.im).collect();
        let br: Vec<f32> = b.iter().map(|z| z.re).collect();
        let bi: Vec<f32> = b.iter().map(|z| z.im).collect();
        let inter = measure("interleaved", || {
            std::hint::black_box(scan::scan_sequential_ti(&a, &b, l, p));
        });
        let planar = measure("planar", || {
            std::hint::black_box(scan::scan_sequential_ti_planar(&ar, &ai, &br, &bi, l, p));
        });
        let mut t = Table::new(&["layout", "time", "elements/s"]);
        t.row(&[
            "interleaved C32".into(),
            fmt_secs(inter.mean),
            format!("{:.0}M", (l * p) as f64 / inter.mean / 1e6),
        ]);
        t.row(&[
            "planar re/im (SoA)".into(),
            fmt_secs(planar.mean),
            format!("{:.0}M", (l * p) as f64 / planar.mean / 1e6),
        ]);
        println!(
            "## §Perf: memory layout of the scan hot loop ({:.2}x)\n{}",
            inter.mean / planar.mean,
            t.render()
        );
        let meps = (l * p) as f64 / 1e6;
        snap.push(("layout_expt/interleaved".into(), inter.mean, meps / inter.mean));
        snap.push(("layout_expt/planar".into(), planar.mean, meps / planar.mean));
    }

    // 5. §Tentpole: the ScanBackend kernels themselves — planar (SoA) vs
    // the interleaved C32 oracle at the engine's serving shape, sequential
    // and chunked-parallel. The per-iteration copy_from_slice reset is
    // identical on both sides, so the reported speedup is a lower bound on
    // the kernel-only win.
    {
        let (lt, pt) = (16384usize, 256usize);
        let a = rand_c32(&mut rng, pt, 0.5);
        let b = rand_c32(&mut rng, lt * pt, 1.0);
        let ar: Vec<f32> = a.iter().map(|z| z.re).collect();
        let ai: Vec<f32> = a.iter().map(|z| z.im).collect();
        let br: Vec<f32> = b.iter().map(|z| z.re).collect();
        let bi: Vec<f32> = b.iter().map(|z| z.im).collect();
        let tthr = max_threads.clamp(2, 8);
        let elems = (lt * pt) as f64;
        let mut scratch = ScanScratch::new();

        let mut buf = b.clone();
        let seq_inter = measure("backend seq interleaved", || {
            buf.copy_from_slice(&b);
            SequentialBackend.scan_ti(&a, &mut buf, lt, pt, &mut scratch);
            std::hint::black_box(&buf);
        });
        let (mut xr, mut xi) = (br.clone(), bi.clone());
        let seq_planar = measure("backend seq planar", || {
            xr.copy_from_slice(&br);
            xi.copy_from_slice(&bi);
            SequentialBackend.scan_ti_planar(&ar, &ai, &mut xr, &mut xi, lt, pt, &mut scratch);
            std::hint::black_box((&xr, &xi));
        });
        let par = ParallelBackend::new(tthr);
        let par_inter = measure(&format!("backend par{tthr} interleaved"), || {
            buf.copy_from_slice(&b);
            par.scan_ti(&a, &mut buf, lt, pt, &mut scratch);
            std::hint::black_box(&buf);
        });
        let par_planar = measure(&format!("backend par{tthr} planar"), || {
            xr.copy_from_slice(&br);
            xi.copy_from_slice(&bi);
            par.scan_ti_planar(&ar, &ai, &mut xr, &mut xi, lt, pt, &mut scratch);
            std::hint::black_box((&xr, &xi));
        });

        let mut t = Table::new(&["backend", "layout", "time", "elements/s"]);
        for (name, layout, st) in [
            ("sequential", "interleaved C32", &seq_inter),
            ("sequential", "planar re/im (SoA)", &seq_planar),
            ("parallel", "interleaved C32", &par_inter),
            ("parallel", "planar re/im (SoA)", &par_planar),
        ] {
            t.row(&[
                name.into(),
                layout.into(),
                fmt_secs(st.mean),
                format!("{:.0}M", elems / st.mean / 1e6),
            ]);
        }
        println!(
            "## ScanBackend planar vs interleaved (TI, L={lt}, P={pt}, T={tthr})\n{}",
            t.render()
        );
        println!(
            "planar speedup: sequential {:.2}x, parallel {:.2}x (acceptance: parallel > 1x)\n",
            seq_inter.mean / seq_planar.mean,
            par_inter.mean / par_planar.mean
        );
        let m = elems / 1e6;
        snap.push(("backend_ti/seq_interleaved".into(), seq_inter.mean, m / seq_inter.mean));
        snap.push(("backend_ti/seq_planar".into(), seq_planar.mean, m / seq_planar.mean));
        snap.push((
            format!("backend_ti/par{tthr}_interleaved"),
            par_inter.mean,
            elems / par_inter.mean / 1e6,
        ));
        snap.push((
            format!("backend_ti/par{tthr}_planar"),
            par_planar.mean,
            elems / par_planar.mean / 1e6,
        ));
    }

    // 6. §Tentpole (worker-pool PR): persistent-pool vs scoped
    // spawn-per-call dispatch of the same planar parallel scan at the
    // serving shape — the per-batch spawn overhead the pool removes.
    // Identical kernels, identical chunking, bit-identical results
    // (tests/scan_matrix.rs); only the dispatch differs. A short-L shape
    // is included because dispatch overhead is amortized at long L but
    // dominates high-rate short-sequence serving.
    {
        let tthr = max_threads.clamp(2, 8);
        let mut t = Table::new(&["shape", "dispatch", "time", "elements/s"]);
        for &(lt, pt, tag) in &[(16384usize, 256usize, "serving"), (2048, 64, "short")] {
            let a = rand_c32(&mut rng, pt, 0.5);
            let b = rand_c32(&mut rng, lt * pt, 1.0);
            let ar: Vec<f32> = a.iter().map(|z| z.re).collect();
            let ai: Vec<f32> = a.iter().map(|z| z.im).collect();
            let br: Vec<f32> = b.iter().map(|z| z.re).collect();
            let bi: Vec<f32> = b.iter().map(|z| z.im).collect();
            let elems = (lt * pt) as f64;
            let scoped_be = ParallelBackend::with_exec(tthr, ScanExec::Scoped);
            let pooled_be = ParallelBackend::new(tthr);
            let mut scratch = ScanScratch::new();
            let (mut xr, mut xi) = (br.clone(), bi.clone());
            let scoped = measure(&format!("pool A/B scoped {tag}"), || {
                xr.copy_from_slice(&br);
                xi.copy_from_slice(&bi);
                scoped_be.scan_ti_planar(&ar, &ai, &mut xr, &mut xi, lt, pt, &mut scratch);
                std::hint::black_box((&xr, &xi));
            });
            let pooled = measure(&format!("pool A/B pooled {tag}"), || {
                xr.copy_from_slice(&br);
                xi.copy_from_slice(&bi);
                pooled_be.scan_ti_planar(&ar, &ai, &mut xr, &mut xi, lt, pt, &mut scratch);
                std::hint::black_box((&xr, &xi));
            });
            for (name, st) in [("scoped spawn-per-call", &scoped), ("persistent pool", &pooled)] {
                t.row(&[
                    format!("L={lt} P={pt}"),
                    name.into(),
                    fmt_secs(st.mean),
                    format!("{:.0}M", elems / st.mean / 1e6),
                ]);
            }
            println!(
                "pool A/B ({tag}, L={lt}, P={pt}, T={tthr}): pooled speedup {:.2}x",
                scoped.mean / pooled.mean
            );
            snap.push((format!("pool_ab_{tag}/scoped"), scoped.mean, elems / scoped.mean / 1e6));
            snap.push((format!("pool_ab_{tag}/pooled"), pooled.mean, elems / pooled.mean / 1e6));
        }
        println!("## persistent pool vs scoped spawn dispatch (planar TI)\n{}", t.render());
    }

    // 7. §Tentpole (fused-tiling PR): the cache-blocked fused
    // drive→scale→scan→project pipeline vs the staged full-plane pipeline
    // through a whole S5 SSM stage, at the serving shape and a short
    // shape. Same kernels per element — the delta is pure memory traffic
    // (the staged path round-trips full (B, L, P2) planes through DRAM
    // four times; the fused path keeps each tile L2-resident) — plus the
    // SsmBuffers footprint, reported per token so the O(B·T·P) claim is
    // measured rather than asserted.
    {
        let tthr = max_threads.clamp(4, 8); // ≥ 4 (sequence × direction) pipelines
        let mut t = Table::new(&["shape", "pipeline", "time", "tokens/s", "ssm bytes/token"]);
        for &(lt, p2t, ht, bt, tag) in
            &[(16384usize, 256usize, 32usize, 4usize, "serving"), (2048, 64, 16, 4, "short")]
        {
            let mut rng2 = Rng::new(11);
            let layer = random_layer(&mut rng2, ht, p2t);
            let u = rng2.normal_vec_f32(bt * lt * ht);
            let mut y = vec![0.0f32; bt * lt * ht];
            let tokens = (bt * lt) as f64;
            let staged_opts =
                ForwardOptions::new().with_threads(tthr).with_tiling(Tiling::Staged);
            let fused_opts = ForwardOptions::new().with_threads(tthr); // Auto tile
            let mut ws_staged = EngineWorkspace::new();
            let mut ws_fused = EngineWorkspace::new();
            // warm both so the measured loops are steady-state (no alloc)
            layer.apply_ssm_batch_opts_into(&u, bt, lt, None, &staged_opts, &mut ws_staged, &mut y);
            layer.apply_ssm_batch_opts_into(&u, bt, lt, None, &fused_opts, &mut ws_fused, &mut y);
            let staged = measure(&format!("fused A/B staged {tag}"), || {
                layer.apply_ssm_batch_opts_into(
                    &u, bt, lt, None, &staged_opts, &mut ws_staged, &mut y,
                );
                std::hint::black_box(&y);
            });
            let fused = measure(&format!("fused A/B fused {tag}"), || {
                layer.apply_ssm_batch_opts_into(
                    &u, bt, lt, None, &fused_opts, &mut ws_fused, &mut y,
                );
                std::hint::black_box(&y);
            });
            let staged_bytes = ws_staged.ssm_capacity_bytes() as f64;
            let fused_bytes = ws_fused.ssm_capacity_bytes() as f64;
            for (name, st, bytes) in
                [("staged full-plane", &staged, staged_bytes), ("fused tiled", &fused, fused_bytes)]
            {
                t.row(&[
                    format!("L={lt} P2={p2t} H={ht} B={bt}"),
                    name.into(),
                    fmt_secs(st.mean),
                    format!("{:.0}k", tokens / st.mean / 1e3),
                    format!("{:.1}", bytes / tokens),
                ]);
            }
            println!(
                "fused A/B ({tag}, L={lt}, P2={p2t}, H={ht}, B={bt}, T={tthr}): \
                 fused speedup {:.2}x, ssm bytes/token {:.1} → {:.1}",
                staged.mean / fused.mean,
                staged_bytes / tokens,
                fused_bytes / tokens
            );
            snap.push((format!("fused_ab_{tag}/staged"), staged.mean, tokens / staged.mean / 1e6));
            snap.push((format!("fused_ab_{tag}/fused"), fused.mean, tokens / fused.mean / 1e6));
            metrics.push((format!("fused_ab_{tag}/staged_ssm_bytes"), staged_bytes));
            metrics.push((format!("fused_ab_{tag}/fused_ssm_bytes"), fused_bytes));
            metrics
                .push((format!("fused_ab_{tag}/staged_ssm_bytes_per_token"), staged_bytes / tokens));
            metrics
                .push((format!("fused_ab_{tag}/fused_ssm_bytes_per_token"), fused_bytes / tokens));
            // the O(B·T·P) claim, measured: doubling L must not move the
            // fused high-water mark (the staged one doubles)
            let l2 = lt * 2;
            let u2 = rng2.normal_vec_f32(bt * l2 * ht);
            let mut y2 = vec![0.0f32; bt * l2 * ht];
            layer.apply_ssm_batch_opts_into(&u2, bt, l2, None, &fused_opts, &mut ws_fused, &mut y2);
            metrics.push((
                format!("fused_ab_{tag}/fused_ssm_bytes_at_2x_l"),
                ws_fused.ssm_capacity_bytes() as f64,
            ));
        }
        println!("## fused cache-blocked vs staged SSM pipeline (TI)\n{}", t.render());
        println!(
            "acceptance: fused speedup > 1x at the serving shape, fused ssm bytes \
             independent of L\n"
        );
    }

    // 8. §Tentpole (single-stream PR): in-tile wide scaling. One stream
    // (B = 1, unidirectional) has a single (sequence × direction)
    // pipeline, so the default fused path uses one worker regardless of
    // the budget. `with_wide` sends the leftover workers *inside* each
    // tile (row-split drive/projection + seeded chunked scan); this
    // measures single-stream tokens/s at 1, 2 and max workers. The
    // snapshot records the pool-width speedup (acceptance: > 1.5x).
    {
        let (lt, p2t, ht) = (16384usize, 256usize, 32usize);
        let mut rng2 = Rng::new(13);
        let layer = random_layer(&mut rng2, ht, p2t);
        let u = rng2.normal_vec_f32(lt * ht);
        let mut y = vec![0.0f32; lt * ht];
        let tokens = lt as f64;
        let mut widths = vec![1usize, 2];
        if max_threads > 2 {
            widths.push(max_threads);
        }
        let mut t = Table::new(&["workers", "time", "tokens/s", "speedup vs 1"]);
        let mut base_mean = f64::NAN;
        let mut max_speedup = 1.0f64;
        for &w in &widths {
            let opts = ForwardOptions::new().with_wide().with_threads(w);
            let mut ws = EngineWorkspace::new();
            // warm so the measured loop is steady-state (no alloc)
            layer.apply_ssm_batch_opts_into(&u, 1, lt, None, &opts, &mut ws, &mut y);
            let st = measure(&format!("single-stream wide t{w}"), || {
                layer.apply_ssm_batch_opts_into(&u, 1, lt, None, &opts, &mut ws, &mut y);
                std::hint::black_box(&y);
            });
            if w == 1 {
                base_mean = st.mean;
            }
            let speedup = base_mean / st.mean;
            max_speedup = max_speedup.max(speedup);
            t.row(&[
                w.to_string(),
                fmt_secs(st.mean),
                format!("{:.0}k", tokens / st.mean / 1e3),
                format!("{speedup:.2}x"),
            ]);
            snap.push((format!("single_stream/t{w}"), st.mean, tokens / st.mean / 1e6));
        }
        metrics.push(("single_stream/wide_speedup_at_pool_width".into(), max_speedup));
        println!(
            "## single-stream in-tile wide scaling (B=1 unidirectional, L={lt}, P2={p2t}, H={ht})\n{}",
            t.render()
        );
        println!("acceptance: tokens/s at pool width > 1.5x one worker\n");
    }

    // 9. §Tentpole (dtype PR): storage-dtype A/B. The fused pipeline with
    // f32 vs bf16 drive planes at the serving shape — identical kernels
    // and f32 accumulation on both arms; bf16 halves the drive-plane
    // bytes each tile streams through the cache, so the delta is pure
    // storage traffic. The workspace footprint is reported per token on
    // both arms so the bytes/token halving is measured, not asserted.
    {
        use s5::ssm::dtype::Dtype;
        let tthr = max_threads.clamp(4, 8);
        let (lt, p2t, ht, bt) = (16384usize, 256usize, 32usize, 4usize);
        let mut rng2 = Rng::new(17);
        let layer = random_layer(&mut rng2, ht, p2t);
        let u = rng2.normal_vec_f32(bt * lt * ht);
        let mut y = vec![0.0f32; bt * lt * ht];
        let tokens = (bt * lt) as f64;
        let mut t = Table::new(&["dtype", "time", "tokens/s", "ssm bytes/token"]);
        let mut means = [f64::NAN; 2];
        let mut bpt = [f64::NAN; 2];
        let arms = [("f32", Dtype::F32), ("bf16", Dtype::Bf16)];
        for (i, (tag, dtype)) in arms.into_iter().enumerate() {
            let opts = ForwardOptions::new().with_threads(tthr).with_dtype(dtype);
            let mut ws = EngineWorkspace::new();
            // warm so the measured loop is steady-state (no alloc)
            layer.apply_ssm_batch_opts_into(&u, bt, lt, None, &opts, &mut ws, &mut y);
            let st = measure(&format!("dtype A/B {tag}"), || {
                layer.apply_ssm_batch_opts_into(&u, bt, lt, None, &opts, &mut ws, &mut y);
                std::hint::black_box(&y);
            });
            let bytes = ws.ssm_capacity_bytes() as f64;
            means[i] = st.mean;
            bpt[i] = bytes / tokens;
            t.row(&[
                tag.into(),
                fmt_secs(st.mean),
                format!("{:.0}k", tokens / st.mean / 1e3),
                format!("{:.1}", bytes / tokens),
            ]);
            snap.push((format!("dtype_ab/{tag}"), st.mean, tokens / st.mean / 1e6));
            metrics.push((format!("dtype_ab/{tag}_ssm_bytes_per_token"), bytes / tokens));
        }
        println!(
            "## storage dtype A/B (fused TI, L={lt}, P2={p2t}, H={ht}, B={bt}, T={tthr})\n{}",
            t.render()
        );
        println!(
            "dtype A/B: bf16 speedup {:.2}x, ssm bytes/token {:.1} → {:.1}\n",
            means[0] / means[1],
            bpt[0],
            bpt[1]
        );
    }

    // 3. linear growth in L
    let mut t = Table::new(&["L", "time", "time/L (ns)"]);
    for &ll in &[4096usize, 8192, 16384, if quick { 16384 } else { 32768 }] {
        let bb = rand_c32(&mut rng, ll * p, 1.0);
        let st = measure(&format!("L{ll}"), || {
            std::hint::black_box(scan::scan_sequential_ti(&a, &bb, ll, p));
        });
        t.row(&[
            ll.to_string(),
            fmt_secs(st.mean),
            format!("{:.2}", st.mean * 1e9 / ll as f64),
        ]);
    }
    println!("## O(L) scaling (time/L should be ~constant)\n{}", t.render());

    // 4. batched engine throughput: one workspace-reusing batched forward
    // vs a loop of single-sequence forwards at the same thread budget.
    {
        let cfg = S5Config { h: 32, p: 32, j: 1, ..Default::default() };
        let model = S5Model::init(4, 10, 2, &cfg, &mut Rng::new(5));
        let lb = if quick { 96 } else { 384 };
        let mut rng = Rng::new(6);
        let mut t = Table::new(&[
            "threads", "B", "batched seq/s", "single-loop seq/s", "batched speedup",
        ]);
        let mut thread_counts = vec![2usize];
        if max_threads > 2 {
            thread_counts.push(max_threads);
        }
        for &threads in &thread_counts {
            let backend = backend_for_threads(threads);
            let mut ws = EngineWorkspace::new();
            for &bsz in &[1usize, 4, 8, 16] {
                let u = rng.normal_vec_f32(bsz * lb * 4);
                let mut out = vec![0.0f32; bsz * 10];
                // warm the workspace so the measured loop is steady-state
                model.forward_batch_into(&u, bsz, lb, 1.0, backend.as_ref(), &mut ws, &mut out);
                let st_batched = measure(&format!("batched T{threads} B{bsz}"), || {
                    model.forward_batch_into(
                        &u,
                        bsz,
                        lb,
                        1.0,
                        backend.as_ref(),
                        &mut ws,
                        &mut out,
                    );
                    std::hint::black_box(&out);
                });
                let st_loop = measure(&format!("single-loop T{threads} B{bsz}"), || {
                    for bi in 0..bsz {
                        std::hint::black_box(model.forward(
                            &u[bi * lb * 4..(bi + 1) * lb * 4],
                            lb,
                            1.0,
                            threads,
                        ));
                    }
                });
                t.row(&[
                    threads.to_string(),
                    bsz.to_string(),
                    format!("{:.1}", bsz as f64 / st_batched.mean),
                    format!("{:.1}", bsz as f64 / st_loop.mean),
                    format!("{:.2}x", st_loop.mean / st_batched.mean),
                ]);
            }
        }
        println!(
            "## batched engine vs single-sequence loop (L={lb}, H=32, 2 layers)\n{}",
            t.render()
        );
        println!("expected shape: batched speedup > 1x from B=4 up at ≥2 threads");
    }

    write_snapshot(&snap, &metrics, quick, max_threads);
}

/// A random stable S5 layer at an explicit (H, P2) — the serving-shape
/// fused-vs-staged A/B wants P2 = 256, where the HiPPO eigendecomposition
/// of `S5Layer::init` would dominate bench startup for no measurement
/// value. Eigenvalues sit in the stable left half-plane; magnitudes match
/// the standard init scalings.
fn random_layer(rng: &mut Rng, h: usize, p2: usize) -> S5Layer {
    let sb = 1.0 / (h as f64).sqrt();
    let sc = (0.5 / p2 as f64).sqrt();
    S5Layer {
        lambda: (0..p2)
            .map(|_| C64::new(-(0.1 + rng.uniform_in(0.0, 1.0)), rng.normal()))
            .collect(),
        b_tilde: (0..p2 * h).map(|_| C64::new(rng.normal(), rng.normal()).scale(sb)).collect(),
        c_tilde: vec![(0..h * p2)
            .map(|_| C64::new(rng.normal(), rng.normal()).scale(sc))
            .collect()],
        d: rng.normal_vec_f32(h),
        log_dt: (0..p2)
            .map(|_| rng.uniform_in((1e-3f64).ln(), (1e-1f64).ln()) as f32)
            .collect(),
        gate_w: rng.normal_vec_f32(h * h),
        norm_scale: vec![1.0; h],
        norm_bias: vec![0.0; h],
        h,
        p2,
    }
}

/// Write the scan-bench snapshot as JSON (hand-rolled — the offline build
/// has no serde) so the perf trajectory is recorded run-over-run. Path:
/// `BENCH_scan.json` in the working directory, or `S5_BENCH_JSON`.
/// Timing rows carry mean seconds + throughput; `metrics` carries scalar
/// measurements (workspace bytes, bytes/token) keyed by name.
fn write_snapshot(
    rows: &[(String, f64, f64)],
    metrics: &[(String, f64)],
    quick: bool,
    max_threads: usize,
) {
    let path = std::env::var("S5_BENCH_JSON").unwrap_or_else(|_| "BENCH_scan.json".into());
    let mut out = String::from("{\n  \"bench\": \"scan_scaling\",\n");
    out.push_str(&format!(
        "  \"quick\": {quick},\n  \"max_threads\": {max_threads},\n  \"results\": [\n"
    ));
    for (i, (name, mean, meps)) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"name\": \"{name}\", \"mean_s\": {mean:.6e}, \"melem_per_s\": {meps:.3}}}{comma}\n"
        ));
    }
    out.push_str("  ],\n  \"metrics\": {\n");
    for (i, (name, value)) in metrics.iter().enumerate() {
        let comma = if i + 1 == metrics.len() { "" } else { "," };
        out.push_str(&format!("    \"{name}\": {value:.3}{comma}\n"));
    }
    out.push_str("  }\n}\n");
    match std::fs::write(&path, &out) {
        Ok(()) => println!("\nwrote scan bench snapshot to {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
