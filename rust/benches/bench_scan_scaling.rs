//! Proposition 1 / §2.2 / Appendix H: parallel-scan scaling measurements.
//!
//! Three claims under measurement:
//!  1. the multi-threaded Blelloch scan speeds up with cores at long L
//!     (work-efficient: total ops stay O(P·L));
//!  2. the dense-A scan is catastrophically more expensive than the
//!     diagonal scan (why S5 diagonalizes, §2.2);
//!  3. scan cost grows linearly in L (vs the FFT path's L·log L).
//!
//! Run: `cargo bench --bench bench_scan_scaling`

use s5::bench::{fmt_secs, measure, quick_mode};
use s5::num::{C32, C64};
use s5::rng::Rng;
use s5::ssm::scan;
use s5::util::Table;

fn rand_c32(rng: &mut Rng, n: usize, scale: f32) -> Vec<C32> {
    (0..n)
        .map(|_| C32::new(rng.normal() as f32 * scale, rng.normal() as f32 * scale))
        .collect()
}

fn main() {
    let quick = quick_mode();
    let l = if quick { 8192 } else { 65536 };
    let p = 64;
    let max_threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(8);

    println!("# Parallel scan scaling (L={l}, P={p})\n");
    let mut rng = Rng::new(1);
    let a = rand_c32(&mut rng, p, 0.5);
    let b = rand_c32(&mut rng, l * p, 1.0);

    // 1. thread scaling
    let mut t = Table::new(&["threads", "time", "speedup vs 1"]);
    let base = measure("seq", || {
        std::hint::black_box(scan::scan_sequential_ti(&a, &b, l, p));
    });
    t.row(&["1 (sequential)".into(), fmt_secs(base.mean), "1.00x".into()]);
    let mut threads = 2;
    while threads <= max_threads {
        let st = measure(&format!("par{threads}"), || {
            std::hint::black_box(scan::scan_parallel_ti(&a, &b, l, p, threads));
        });
        t.row(&[
            threads.to_string(),
            fmt_secs(st.mean),
            format!("{:.2}x", base.mean / st.mean),
        ]);
        threads *= 2;
    }
    println!("## thread scaling (time-invariant diagonal scan)\n{}", t.render());

    // 2. dense vs diagonal (small L: dense is O(P²) per step sequentially)
    let ld = if quick { 512 } else { 2048 };
    let mut t = Table::new(&["state matrix", "time", "ratio"]);
    let b64: Vec<C64> = (0..ld * p).map(|_| C64::new(rng.normal(), rng.normal())).collect();
    let mut dense = vec![C64::ZERO; p * p];
    for i in 0..p {
        for j in 0..p {
            dense[i * p + j] = C64::new(rng.normal() * 0.05, rng.normal() * 0.05);
        }
    }
    let bd = rand_c32(&mut rng, ld * p, 1.0);
    let diag_st = measure("diag", || {
        std::hint::black_box(scan::scan_sequential_ti(&a, &bd, ld, p));
    });
    let dense_st = measure("dense", || {
        std::hint::black_box(scan::scan_dense_sequential(&dense, &b64, ld, p));
    });
    t.row(&["diagonal (P ops/step)".into(), fmt_secs(diag_st.mean), "1.0x".into()]);
    t.row(&[
        "dense (P² ops/step)".into(),
        fmt_secs(dense_st.mean),
        format!("{:.1}x slower", dense_st.mean / diag_st.mean),
    ]);
    println!("## dense vs diagonal at L={ld} (why S5 diagonalizes, §2.2)\n{}", t.render());

    // §Perf experiment: interleaved C32 vs planar (struct-of-arrays) layout
    {
        let ar: Vec<f32> = a.iter().map(|z| z.re).collect();
        let ai: Vec<f32> = a.iter().map(|z| z.im).collect();
        let br: Vec<f32> = b.iter().map(|z| z.re).collect();
        let bi: Vec<f32> = b.iter().map(|z| z.im).collect();
        let inter = measure("interleaved", || {
            std::hint::black_box(scan::scan_sequential_ti(&a, &b, l, p));
        });
        let planar = measure("planar", || {
            std::hint::black_box(scan::scan_sequential_ti_planar(&ar, &ai, &br, &bi, l, p));
        });
        let mut t = Table::new(&["layout", "time", "elements/s"]);
        t.row(&[
            "interleaved C32".into(),
            fmt_secs(inter.mean),
            format!("{:.0}M", (l * p) as f64 / inter.mean / 1e6),
        ]);
        t.row(&[
            "planar re/im (SoA)".into(),
            fmt_secs(planar.mean),
            format!("{:.0}M", (l * p) as f64 / planar.mean / 1e6),
        ]);
        println!(
            "## §Perf: memory layout of the scan hot loop ({:.2}x)\n{}",
            inter.mean / planar.mean,
            t.render()
        );
    }

    // 3. linear growth in L
    let mut t = Table::new(&["L", "time", "time/L (ns)"]);
    for &ll in &[4096usize, 8192, 16384, if quick { 16384 } else { 32768 }] {
        let bb = rand_c32(&mut rng, ll * p, 1.0);
        let st = measure(&format!("L{ll}"), || {
            std::hint::black_box(scan::scan_sequential_ti(&a, &bb, ll, p));
        });
        t.row(&[
            ll.to_string(),
            fmt_secs(st.mean),
            format!("{:.2}", st.mean * 1e9 / ll as f64),
        ]);
    }
    println!("## O(L) scaling (time/L should be ~constant)\n{}", t.render());
}
