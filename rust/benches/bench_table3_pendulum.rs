//! Paper Table 3/9: pendulum regression — MSE and *relative speed* of S5
//! vs per-step sequential baselines (CRU-like, GRU).
//!
//! Speed methodology mirrors the paper's "relative application speed"
//! column: all models process the same encoded observation sequences; the
//! sequential baselines must step one observation at a time (GRU: dense
//! per-step gates; CRU-like: + per-step covariance matrix propagation),
//! while S5 applies one parallel scan. MSE comes from actually training
//! the S5 regressor through the PJRT train-step artifact.
//!
//! Run: `cargo bench --bench bench_table3_pendulum`

#![allow(deprecated)] // legacy positional wrappers are the subjects/oracles here

use s5::bench::{fmt_secs, measure, quick_mode};
use s5::coordinator::{TrainConfig, Trainer};
use s5::rng::Rng;
use s5::runtime::Client;
use s5::ssm::rnn::{CruLike, GruCell};
use s5::ssm::s5::{S5Config, S5Layer};
use s5::util::Table;
use std::path::Path;

fn main() {
    let quick = quick_mode();
    // the paper's setting: H=30 features, L=50 observations — but speed
    // differences only show at scale, so we also measure a longer horizon.
    let h = 30;
    let lengths: &[usize] = if quick { &[50, 512] } else { &[50, 1024, 4096] };

    println!("# Table 3/9 reproduction — pendulum regression\n");

    // --- relative application speed (paper: S5 130x vs CRU) ---
    let mut rng = Rng::new(3);
    let s5 = S5Layer::init(&S5Config { h, p: 16, j: 2, ..Default::default() }, &mut rng);
    let gru = GruCell::init(h, h, &mut rng);
    let cru = CruLike::init(h, h, &mut rng);
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);

    for &l in lengths {
        let xs = Rng::new(l as u64).normal_vec_f32(l * h);
        let dts: Vec<f32> = Rng::new(9).uniform_vec_f32(l, 0.5, 2.0);
        let mut t = Table::new(&["model", "time / sequence", "relative speed"]);
        let cru_st = measure("cru", || {
            std::hint::black_box(cru.run(&xs, &dts, l));
        });
        let gru_st = measure("gru", || {
            std::hint::black_box(gru.run(&xs, l));
        });
        let s5_st = measure("s5", || {
            std::hint::black_box(s5.apply_ssm(&xs, l, 1.0, Some(&dts), threads));
        });
        t.row(&["CRU-like (seq + cov)".into(), fmt_secs(cru_st.mean), "1.00x".into()]);
        t.row(&[
            "GRU (sequential)".into(),
            fmt_secs(gru_st.mean),
            format!("{:.1}x", cru_st.mean / gru_st.mean),
        ]);
        t.row(&[
            "S5 (parallel scan, var-Δt)".into(),
            fmt_secs(s5_st.mean),
            format!("{:.1}x", cru_st.mean / s5_st.mean),
        ]);
        println!("## application speed at L={l} (paper: S5 130x vs CRU at their scale)\n{}", t.render());
    }

    // --- regression MSE via the real train-step artifact ---
    if Path::new("artifacts/pendulum_train.hlo.txt").exists() {
        let steps = if quick { 10 } else { 120 };
        println!("## training S5 regressor for {steps} steps (paper: MSE 3.38e-3)");
        let client = Client::cpu().expect("client");
        let mut cfg = TrainConfig::for_preset("pendulum");
        cfg.steps = steps;
        cfg.eval_pool = 48;
        cfg.eval_every = 0;
        let mut trainer = Trainer::new(&client, cfg).expect("trainer");
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            trainer.train_step().expect("step");
        }
        let train_wall = t0.elapsed().as_secs_f64();
        let (mse, _) = trainer.evaluate().expect("eval");
        println!("  held-out MSE: {:.2}e-3 after {steps} steps ({:.1}s)", mse * 1e3, train_wall);
        let ema = trainer.log.ema_loss(0.1);
        println!(
            "  train MSE: {:.2}e-3 → {:.2}e-3 (must decrease)",
            ema[0] * 1e3,
            ema[ema.len() - 1] * 1e3
        );
    } else {
        eprintln!("pendulum artifacts missing — MSE section skipped");
    }
}
