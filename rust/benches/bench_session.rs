//! Streaming-session throughput: tokens/sec of `Session::step` for the
//! unified `SequenceModel` API, next to the per-token cost of the batched
//! offline prefill over the same models (the Prop. 1 online-vs-offline
//! comparison, measured on the serving surface instead of raw kernels).
//!
//! Run: `cargo bench --bench bench_session`  (S5_BENCH_QUICK=1 for CI)

use s5::bench::quick_mode;
use s5::rng::Rng;
use s5::ssm::api::{Batch, ForwardOptions, SequenceModel, Session};
use s5::ssm::engine::EngineWorkspace;
use s5::ssm::rnn::{CruLike, GruCell};
use s5::ssm::s5::{S5Config, S5Model};
use s5::util::Table;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let quick = quick_mode();
    let (d_in, h, depth) = (4usize, if quick { 16 } else { 32 }, if quick { 2 } else { 4 });
    let tokens = if quick { 512usize } else { 8192 };
    let repeats = if quick { 2 } else { 5 };

    let cfg = S5Config { h, p: h, j: 1, ..Default::default() };
    let models: Vec<(&str, Arc<dyn SequenceModel>)> = vec![
        ("s5", Arc::new(S5Model::init(d_in, 10, depth, &cfg, &mut Rng::new(1)))),
        ("gru", Arc::new(GruCell::init(d_in, h, &mut Rng::new(2)))),
        ("cru-like", Arc::new(CruLike::init(d_in, h, &mut Rng::new(3)))),
    ];

    println!(
        "# Session step throughput vs batched prefill ({tokens} tokens, H={h}, depth {depth})\n"
    );
    let mut table = Table::new(&[
        "model", "step tokens/s", "prefill tokens/s (seq)", "prefill tokens/s (par)",
    ]);
    let mut rng = Rng::new(9);
    for (name, model) in models {
        let u = rng.normal_vec_f32(tokens * d_in);

        // streaming: one Session driven token by token
        let mut best_step = f64::MAX;
        for _ in 0..repeats {
            let mut session = Session::new(model.clone(), ForwardOptions::new());
            let t0 = Instant::now();
            for k in 0..tokens {
                std::hint::black_box(session.step(&u[k * d_in..(k + 1) * d_in]));
            }
            best_step = best_step.min(t0.elapsed().as_secs_f64());
        }

        // offline: the same tokens as one packed prefill
        let mut ws = EngineWorkspace::new();
        let mut prefill_rate = |threads: usize| {
            let opts = ForwardOptions::new().with_threads(threads);
            let mut best = f64::MAX;
            for _ in 0..repeats {
                let t0 = Instant::now();
                std::hint::black_box(model.prefill(
                    Batch::single(&u, tokens, d_in),
                    &opts,
                    &mut ws,
                ));
                best = best.min(t0.elapsed().as_secs_f64());
            }
            tokens as f64 / best
        };
        let seq = prefill_rate(1);
        let par = prefill_rate(0);

        table.row(&[
            name.to_string(),
            format!("{:.0}", tokens as f64 / best_step),
            format!("{seq:.0}"),
            format!("{par:.0}"),
        ]);
    }
    println!("{}", table.render());
    println!("session bench OK ✓");
}
