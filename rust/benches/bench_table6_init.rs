//! Paper Table 6: initialization & parameterization ablation —
//! {discrete, continuous} × {Gaussian, antisymmetric, HiPPO-N} on a
//! ListOps-style task. The paper's finding: only continuous-time
//! parameterization + HiPPO-N is consistently strong; discrete/HiPPO-N is
//! unstable to train.
//!
//! Each cell is a separate AOT artifact (the parameterization changes the
//! lowered graph, not just the init values), trained through PJRT with
//! identical budget/seed.
//!
//! Run: `cargo bench --bench bench_table6_init`

use s5::coordinator::{TrainConfig, Trainer};
use s5::runtime::Client;
use s5::util::Table;
use std::path::Path;

fn main() {
    let steps: usize = std::env::var("S5_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if s5::bench::quick_mode() { 8 } else { 80 });

    println!("# Table 6 reproduction — init × parameterization ({steps} steps, ListOps-256)\n");
    let client = Client::cpu().expect("client");
    let mut table = Table::new(&["parameterization", "initialization", "loss", "acc %", "finite"]);
    let mut accs = std::collections::BTreeMap::new();
    for par in ["discrete", "continuous"] {
        for init in ["gaussian", "antisymmetric", "hippo"] {
            let preset = format!("abl6_{par}_{init}");
            if !Path::new("artifacts")
                .join(format!("{preset}_train.hlo.txt"))
                .exists()
            {
                eprintln!("skipping {preset} (artifact missing)");
                continue;
            }
            let mut cfg = TrainConfig::for_preset(&preset);
            cfg.steps = steps;
            cfg.train_pool = 192;
            cfg.eval_pool = 64;
            cfg.eval_every = 0;
            cfg.seed = 11;
            // the paper notes discrete+HiPPO needs a much lower LR to train
            if par == "discrete" {
                cfg.base_lr *= 0.3;
            }
            let mut trainer = Trainer::new(&client, cfg).expect("trainer");
            let mut finite = true;
            for _ in 0..steps {
                let (loss, _) = trainer.train_step().expect("step");
                if !loss.is_finite() {
                    finite = false;
                    break;
                }
            }
            let (loss, acc) = if finite {
                trainer.evaluate().unwrap_or((f64::NAN, 0.0))
            } else {
                (f64::NAN, 0.0)
            };
            // a NaN at eval also counts as divergence (paper: discrete
            // parameterizations are hard to train at normal LRs)
            finite = finite && loss.is_finite();
            eprintln!("  {preset}: loss={loss:.4} acc={:.1}%", acc * 100.0);
            accs.insert((par, init), acc);
            table.row(&[
                par.to_string(),
                init.to_string(),
                format!("{loss:.4}"),
                format!("{:.1}", acc * 100.0),
                if finite { "✓".into() } else { "✗ diverged".into() },
            ]);
        }
    }
    println!("{}", table.render());
    println!("paper shape (Table 6, ListOps col): continuous+HiPPO-N 62.15 best;");
    println!("discrete variants weaker; discrete+HiPPO-N hard to train.");
    if let (Some(&best), Some(&disc)) = (
        accs.get(&("continuous", "hippo")),
        accs.get(&("discrete", "gaussian")),
    ) {
        println!(
            "continuous+HiPPO-N ≥ discrete+Gaussian: {}",
            if best >= disc - 0.05 { "✓" } else { "✗ (budget too small)" }
        );
    }
}
