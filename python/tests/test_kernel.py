"""L1 kernel correctness: Pallas scan vs the two jnp oracles.

This is the CORE correctness signal for the compute hot-spot: the
hypothesis sweeps cover shapes, magnitudes and degenerate cases, and the
gradient tests pin the custom_vjp (reverse-scan adjoint) against plain
autodiff through the sequential reference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.scan import scan_ssm, scan_ssm_planar

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-4
RTOL = 2e-4


def _rand_complex(rng, shape, scale=0.6):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(
        np.complex64
    ) * scale


def _assert_close(a, b, atol=ATOL, rtol=RTOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ----------------------------------------------------------------- fixed cases

def test_scan_matches_sequential_basic():
    rng = np.random.default_rng(0)
    a = _rand_complex(rng, (64, 8))
    b = _rand_complex(rng, (64, 8))
    _assert_close(scan_ssm(a, b), ref.scan_ref_sequential(a, b))


def test_scan_matches_associative_basic():
    rng = np.random.default_rng(1)
    a = _rand_complex(rng, (100, 4))
    b = _rand_complex(rng, (100, 4))
    _assert_close(scan_ssm(a, b), ref.scan_ref_associative(a, b))


def test_scan_length_one():
    rng = np.random.default_rng(2)
    a = _rand_complex(rng, (1, 3))
    b = _rand_complex(rng, (1, 3))
    # x_1 = b_1 regardless of a (x_0 = 0).
    _assert_close(scan_ssm(a, b), b)


def test_scan_identity_multiplier_is_cumsum():
    rng = np.random.default_rng(3)
    b = _rand_complex(rng, (33, 5))
    a = np.ones_like(b)
    _assert_close(scan_ssm(a, b), np.cumsum(b, axis=0), atol=1e-3, rtol=1e-3)


def test_scan_zero_multiplier_is_identity():
    rng = np.random.default_rng(4)
    b = _rand_complex(rng, (17, 2))
    a = np.zeros_like(b)
    _assert_close(scan_ssm(a, b), b)


def test_scan_stable_decay_long_sequence():
    """|a| < 1 keeps the state bounded over a long horizon (no blowup)."""
    rng = np.random.default_rng(5)
    p = 4
    a = np.broadcast_to(
        (0.99 * np.exp(1j * rng.uniform(0, np.pi, p))).astype(np.complex64), (2048, p)
    )
    b = _rand_complex(rng, (2048, p), scale=0.1)
    xs = np.asarray(scan_ssm(jnp.asarray(a), jnp.asarray(b)))
    assert np.isfinite(xs).all()
    _assert_close(xs, ref.scan_ref_sequential(a, b), atol=2e-3, rtol=2e-3)


def test_scan_non_power_of_two_lengths():
    rng = np.random.default_rng(6)
    for length in (3, 5, 50, 127, 129, 784):
        a = _rand_complex(rng, (length, 2))
        b = _rand_complex(rng, (length, 2))
        _assert_close(scan_ssm(a, b), ref.scan_ref_sequential(a, b))


def test_scan_wide_state_tiling():
    """P larger than the kernel tile exercises the grid dimension."""
    rng = np.random.default_rng(7)
    a = _rand_complex(rng, (32, 192))
    b = _rand_complex(rng, (32, 192))
    _assert_close(scan_ssm(a, b), ref.scan_ref_sequential(a, b))


def test_scan_under_vmap():
    rng = np.random.default_rng(8)
    a = _rand_complex(rng, (4, 40, 6))
    b = _rand_complex(rng, (4, 40, 6))
    got = jax.vmap(scan_ssm)(jnp.asarray(a), jnp.asarray(b))
    want = jax.vmap(ref.scan_ref_sequential)(jnp.asarray(a), jnp.asarray(b))
    _assert_close(got, want)


# ------------------------------------------------------------------ hypothesis

@settings(max_examples=40, deadline=None)
@given(
    length=st.integers(min_value=1, max_value=300),
    p=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=0.05, max_value=0.95),
)
def test_scan_matches_oracles_property(length, p, seed, scale):
    rng = np.random.default_rng(seed)
    a = _rand_complex(rng, (length, p), scale)
    b = _rand_complex(rng, (length, p), 1.0)
    got = scan_ssm(jnp.asarray(a), jnp.asarray(b))
    _assert_close(got, ref.scan_ref_sequential(a, b), atol=5e-4, rtol=5e-3)
    _assert_close(got, ref.scan_ref_associative(a, b), atol=5e-4, rtol=5e-3)


@settings(max_examples=20, deadline=None)
@given(
    length=st.integers(min_value=2, max_value=100),
    p=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_scan_gradients_match_reference(length, p, seed):
    """custom_vjp adjoint ≡ autodiff through the sequential reference."""
    rng = np.random.default_rng(seed)
    args = [
        jnp.asarray(rng.standard_normal((length, p)) * 0.5, jnp.float32)
        for _ in range(4)
    ]
    w = jnp.asarray(rng.standard_normal((length, p)), jnp.float32)

    def obj_pallas(ar, ai, br, bi):
        xr, xi = scan_ssm_planar(ar, ai, br, bi)
        return jnp.sum(w * xr + 0.5 * w * xi)

    def obj_ref(ar, ai, br, bi):
        xs = ref.scan_ref_sequential(ar + 1j * ai, br + 1j * bi)
        return jnp.sum(w * jnp.real(xs) + 0.5 * w * jnp.imag(xs))

    g1 = jax.grad(obj_pallas, argnums=(0, 1, 2, 3))(*args)
    g2 = jax.grad(obj_ref, argnums=(0, 1, 2, 3))(*args)
    for u, v in zip(g1, g2):
        _assert_close(u, v, atol=1e-3, rtol=1e-2)


def test_scan_gradient_time_varying_multipliers():
    """Gradients flow to per-step Ā_k (the irregular-sampling path, §6.3)."""
    rng = np.random.default_rng(11)
    length, p = 30, 3
    ar = jnp.asarray(rng.standard_normal((length, p)) * 0.4, jnp.float32)
    ai = jnp.asarray(rng.standard_normal((length, p)) * 0.4, jnp.float32)
    br = jnp.asarray(rng.standard_normal((length, p)), jnp.float32)
    bi = jnp.asarray(rng.standard_normal((length, p)), jnp.float32)

    def obj(ar):
        xr, xi = scan_ssm_planar(ar, ai, br, bi)
        return jnp.sum(xr**2 + xi**2)

    g = jax.grad(obj)(ar)
    # finite-difference check on a handful of coordinates
    eps = 1e-3
    for (i, j) in [(0, 0), (5, 1), (29, 2), (15, 0)]:
        e = jnp.zeros_like(ar).at[i, j].set(eps)
        fd = (obj(ar + e) - obj(ar - e)) / (2 * eps)
        assert abs(float(g[i, j]) - float(fd)) < 5e-2, (i, j, float(g[i, j]), float(fd))


def test_binary_operator_associativity():
    """Appendix H eq. (50)-(55): the scan operator is associative."""
    rng = np.random.default_rng(12)
    els = [
        (jnp.asarray(_rand_complex(rng, (5,))), jnp.asarray(_rand_complex(rng, (5,))))
        for _ in range(3)
    ]
    lhs = ref.binary_operator(ref.binary_operator(els[0], els[1]), els[2])
    rhs = ref.binary_operator(els[0], ref.binary_operator(els[1], els[2]))
    _assert_close(lhs[0], rhs[0], atol=1e-5, rtol=1e-5)
    _assert_close(lhs[1], rhs[1], atol=1e-5, rtol=1e-5)
