"""L2 model tests: layer semantics, training dynamics, ablation modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(42)


def test_layer_shapes():
    lp = model.init_s5_layer(KEY, h=8, p=8, j=1)
    u = jax.random.normal(KEY, (64, 8))
    y = model.s5_layer_apply(lp, u)
    assert y.shape == (64, 8)
    assert jnp.isfinite(y).all()


def test_ssm_matches_listing1_reference():
    """The planar-kernel SSM path must equal Listing 1's apply_ssm."""
    lp = model.init_s5_layer(KEY, h=6, p=8, j=1)
    u = jax.random.normal(jax.random.PRNGKey(7), (40, 6))
    got = model.s5_ssm_apply(lp, u)

    lam = (lp["lambda_re"] + 1j * lp["lambda_im"]).astype(jnp.complex64)
    dt = jnp.exp(lp["log_dt"])
    lam_bar = jnp.exp(lam * dt)
    b_tilde = (lp["b_re"] + 1j * lp["b_im"]).astype(jnp.complex64)
    b_bar = ((lam_bar - 1.0) / lam)[:, None] * b_tilde
    c_tilde = (lp["c_re"][0] + 1j * lp["c_im"][0]).astype(jnp.complex64)
    want = ref.apply_ssm_ref(lam_bar, b_bar, c_tilde, lp["d"], u, conj_sym=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-3)


def test_timescale_rescaling_matches_dt_change():
    """timescale ρ must act exactly like scaling every Δ (zero-shot transfer)."""
    lp = model.init_s5_layer(KEY, h=4, p=8, j=1)
    u = jax.random.normal(jax.random.PRNGKey(3), (32, 4))
    y1 = model.s5_ssm_apply(lp, u, timescale=2.0)
    lp2 = dict(lp)
    lp2["log_dt"] = lp["log_dt"] + jnp.log(2.0)
    y2 = model.s5_ssm_apply(lp2, u, timescale=1.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)


def test_variable_dt_constant_equals_fixed():
    """dts = 1 everywhere must reproduce the time-invariant path (§6.3)."""
    lp = model.init_s5_layer(KEY, h=4, p=8, j=2)
    u = jax.random.normal(jax.random.PRNGKey(9), (25, 4))
    y_fixed = model.s5_ssm_apply(lp, u)
    y_var = model.s5_ssm_apply(lp, u, dts=jnp.ones(25))
    np.testing.assert_allclose(np.asarray(y_fixed), np.asarray(y_var), atol=1e-5, rtol=1e-4)


def test_variable_dt_changes_output():
    lp = model.init_s5_layer(KEY, h=4, p=8, j=1)
    u = jax.random.normal(jax.random.PRNGKey(9), (25, 4))
    dts = jnp.linspace(0.5, 3.0, 25)
    y1 = model.s5_ssm_apply(lp, u)
    y2 = model.s5_ssm_apply(lp, u, dts=dts)
    assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-4


def test_bidirectional_layer():
    lp = model.init_s5_layer(KEY, h=6, p=8, j=1, bidir=True)
    u = jax.random.normal(KEY, (30, 6))
    y = model.s5_layer_apply(lp, u, bidir=True)
    assert y.shape == (30, 6)
    # A bidirectional layer must NOT be causal: changing a late input
    # perturbs early outputs.
    u2 = u.at[-1, 0].add(1.0)
    y2 = model.s5_layer_apply(lp, u2, bidir=True)
    assert float(jnp.max(jnp.abs(y[:5] - y2[:5]))) > 1e-6


def test_unidirectional_layer_is_causal():
    lp = model.init_s5_layer(KEY, h=6, p=8, j=1)
    u = jax.random.normal(KEY, (30, 6))
    y = model.s5_layer_apply(lp, u)
    u2 = u.at[-1, 0].add(10.0)
    y2 = model.s5_layer_apply(lp, u2)
    np.testing.assert_allclose(np.asarray(y[:-1]), np.asarray(y2[:-1]), atol=1e-6)


@pytest.mark.parametrize("init", ["hippo", "gaussian", "antisymmetric"])
@pytest.mark.parametrize("param", ["continuous", "discrete"])
def test_ablation_modes_run(init, param):
    """Every Table-6 cell must be constructible and finite."""
    lp = model.init_s5_layer(KEY, h=4, p=8, j=1, init=init, parameterization=param)
    u = jax.random.normal(KEY, (20, 4))
    y = model.s5_layer_apply(lp, u, parameterization=param)
    assert jnp.isfinite(y).all()


def test_scalar_dt_ablation():
    lp = model.init_s5_layer(KEY, h=4, p=8, j=1, scalar_dt=True)
    assert lp["log_dt"].shape == (1,)
    u = jax.random.normal(KEY, (20, 4))
    assert jnp.isfinite(model.s5_layer_apply(lp, u)).all()


def test_classifier_train_step_learns():
    """A few steps of the exported train step must fit a toy problem."""
    params = model.init_classifier(KEY, d_input=2, n_classes=2, depth=2, h=8, p=8, j=1)
    # class 0: constant +1 in channel 0; class 1: constant -1.
    x = jnp.concatenate(
        [jnp.ones((4, 32, 1)), -jnp.ones((4, 32, 1))], axis=0
    )
    x = jnp.concatenate([x, jnp.zeros_like(x)], axis=-1)
    y = jnp.array([0, 0, 0, 0, 1, 1, 1, 1])
    tstep = jax.jit(model.make_classifier_train_step())
    m = model.zeros_like_tree(params)
    v = model.zeros_like_tree(params)
    losses = []
    for step in range(30):
        params, m, v, loss, acc = tstep(
            params, m, v, jnp.float32(5e-3), jnp.float32(0.0),
            jnp.float32(step + 1), x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.6, losses
    assert float(acc) == 1.0


def test_adamw_weight_decay_masks():
    """SSM leaves get no decay + scaled LR; dense kernels get both."""
    params = {"lambda_re": jnp.ones(4), "w": jnp.ones((2, 2))}
    grads = model.zeros_like_tree(params)
    m = model.zeros_like_tree(params)
    v = model.zeros_like_tree(params)
    p2, _, _ = model.adamw_update(params, grads, m, v, lr=0.1, wd=0.5,
                                  step=jnp.float32(1.0))
    # zero grads: only decay moves parameters.
    np.testing.assert_allclose(np.asarray(p2["lambda_re"]), 1.0)
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0 - 0.1 * 0.5)


def test_retrieval_two_tower():
    params = model.init_classifier(KEY, d_input=4, n_classes=2, depth=1, h=8,
                                   p=8, j=1)
    # retrieval decoder consumes 4H features
    params["decoder"] = model.init_linear(KEY, 32, 2)
    u1 = jax.random.normal(KEY, (2, 16, 4))
    u2 = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 4))
    logits = model.batched_retrieval_apply(params, u1, u2)
    assert logits.shape == (2, 2)


def test_pendulum_model_shapes():
    params = model.init_pendulum_model(KEY, depth=2, h=30, p=16, j=2)
    imgs = jax.random.normal(KEY, (2, 10, 24, 24))
    dts = jnp.ones((2, 10)) * 0.5
    out = model.batched_pendulum_apply(params, imgs, dts)
    assert out.shape == (2, 10, 2)
    assert jnp.isfinite(out).all()


def test_pendulum_train_step_learns():
    params = model.init_pendulum_model(KEY, depth=1, h=16, p=8, j=1)
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(rng.standard_normal((4, 8, 24, 24)), jnp.float32) * 0.1
    dts = jnp.ones((4, 8), jnp.float32)
    tgt = jnp.zeros((4, 8, 2), jnp.float32)
    tstep = jax.jit(model.make_pendulum_train_step())
    m = model.zeros_like_tree(params)
    v = model.zeros_like_tree(params)
    first = None
    for step in range(15):
        params, m, v, loss, _ = tstep(params, m, v, jnp.float32(1e-2),
                                      jnp.float32(0.0), jnp.float32(step + 1),
                                      imgs, dts, tgt)
        first = first if first is not None else float(loss)
    assert float(loss) < first
