"""Emit the committed golden parity fixtures under ``rust/tests/fixtures/``.

The Rust engine's only cross-language pin: this script runs the
``python/compile`` reference (hippo init, ZOH discretization, the scan
oracles, ``s5_ssm_apply`` / ``s5_layer_apply`` / the classifier) on small
fixed-seed cases and commits inputs plus expected outputs as npz files the
pure-Rust ``runtime/npz.rs`` reader can load. ``rust/tests/parity_fixtures.rs``
pins the engine against every file with per-module tolerances.

Conventions (dictated by the Rust loader):

* every tensor is stored float32 (complex values as ``<name>_re``/``<name>_im``
  planes) — the Rust loader would downcast ``<f8`` members to f32 anyway, so
  committing f64 buys nothing on the consuming side;
* expected values for the *kernel-level* fixtures (init eigenvalues,
  discretization, scans) are computed in float64/complex128 first, so the
  committed f32 value is the correctly rounded ground truth;
* *module-level* expectations (ssm/layer/logits) come from the JAX reference
  functions themselves — the oracle is the reference implementation, rounding
  warts and all, and the Rust-side tolerances are sized for the f32-vs-mixed
  precision gap (measured by ``test_fixture_parity.py``);
* ``MANIFEST.txt`` records per-file crc32/size and per-tensor shapes so the
  Rust suite can prove the committed files parse before trusting any of them.

Run offline from ``python/``:  ``python tests/gen_fixtures.py``
Deterministic: JAX threefry keys + fixed numpy seeds, no network, CPU-only.
"""
from __future__ import annotations

import os
import sys
import zlib
from pathlib import Path

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from compile import hippo, model  # noqa: E402

REPO = Path(__file__).resolve().parents[2]
OUT = REPO / "rust" / "tests" / "fixtures"

F32 = np.float32


def _f32(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64).astype(F32)


def _planes(z, prefix: str) -> dict:
    z = np.asarray(z)
    return {f"{prefix}_re": z.real.astype(F32), f"{prefix}_im": z.imag.astype(F32)}


def _zoh(lam: np.ndarray, dt: np.ndarray):
    """float64 ZOH: Λ̄ = exp(ΛΔ), f = (Λ̄ − 1)/Λ (the eq. 6 pair)."""
    lam = lam.astype(np.complex128)
    dt = dt.astype(np.float64)
    lam_bar = np.exp(lam * dt)
    scale = (lam_bar - 1.0) / lam
    return lam_bar, scale


def _scan_sequential(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """x_k = a_k ∘ x_{k−1} + b_k in complex128; a is (P,) or (L, P)."""
    length, p = b.shape
    a = np.broadcast_to(np.asarray(a, np.complex128), (length, p))
    x = np.zeros(p, np.complex128)
    out = np.empty((length, p), np.complex128)
    for k in range(length):
        x = a[k] * x + b[k].astype(np.complex128)
        out[k] = x
    return out


# --------------------------------------------------------------------------
# Fixture builders
# --------------------------------------------------------------------------

def fx_hippo() -> dict:
    """Block-diagonal HiPPO-N init: eigenvalues per (p, j, conj_sym) case.

    Only eigenvalues are pinned: eigen*vector* phases are solver-specific
    (LAPACK here, cyclic Jacobi in Rust), so V itself is not comparable —
    the model-level fixtures cover the eigenbasis end-to-end by exporting
    concrete B̃/C̃ parameters instead.
    """
    arrays = {}
    cases = [(8, 1, True), (16, 4, True), (8, 2, False)]
    for i, (p, j, conj) in enumerate(cases):
        lam, _v, _vinv = hippo.block_diag_hippo_init(p, j, conj)
        arrays[f"case{i}.meta"] = _f32([p, j, 1.0 if conj else 0.0])
        arrays.update(_planes(lam, f"case{i}.lambda"))
    return arrays


def fx_discretize() -> dict:
    """ZOH discretization of the HiPPO-N spectrum, vector and scalar Δt."""
    lam, _v, _vinv = hippo.block_diag_hippo_init(16, 1, True)  # P2 = 8
    lam = np.asarray(lam)
    arrays = dict(_planes(lam, "lambda"))

    dt_vec = np.geomspace(1e-3, 1e-1, lam.shape[0])
    lam_bar, scale = _zoh(lam, dt_vec)
    arrays["vec.dt"] = _f32(dt_vec)
    arrays.update(_planes(lam_bar, "vec.lam_bar"))
    arrays.update(_planes(scale, "vec.scale"))

    dt_s = np.array([0.02])
    lam_bar, scale = _zoh(lam, dt_s)
    arrays["scalar.dt"] = _f32(dt_s)
    arrays.update(_planes(lam_bar, "scalar.lam_bar"))
    arrays.update(_planes(scale, "scalar.scale"))
    return arrays


def fx_scan_ti() -> dict:
    """Time-invariant linear recurrence: realistic Λ̄ magnitudes, L = 48."""
    rng = np.random.default_rng(11)
    p2, length = 6, 48
    lam = -0.5 - 1j * np.arange(1, p2 + 1, dtype=np.float64) * 2.0
    a = np.exp(lam * rng.uniform(0.01, 0.08, p2))
    drive = (rng.standard_normal((length, p2))
             + 1j * rng.standard_normal((length, p2))) * 0.5
    # the committed drive is f32; the reference must scan the f32 values
    a32, d32 = a.astype(np.complex64), drive.astype(np.complex64)
    xs = _scan_sequential(a32.astype(np.complex128), d32.astype(np.complex128))
    arrays = dict(_planes(a32, "a"))
    arrays.update(_planes(d32, "drive"))
    arrays.update(_planes(xs, "x"))
    return arrays


def fx_scan_tv() -> dict:
    """Time-varying multipliers (irregular-Δt shape), L = 40."""
    rng = np.random.default_rng(13)
    p2, length = 5, 40
    lam = -0.5 - 1j * np.linspace(0.5, 9.0, p2)
    dts = rng.uniform(0.3, 2.5, (length, 1)) * rng.uniform(0.01, 0.08, (1, p2))
    a = np.exp(lam[None, :] * dts)
    drive = (rng.standard_normal((length, p2))
             + 1j * rng.standard_normal((length, p2))) * 0.5
    a32, d32 = a.astype(np.complex64), drive.astype(np.complex64)
    xs = _scan_sequential(a32.astype(np.complex128), d32.astype(np.complex128))
    arrays = dict(_planes(a32, "a"))
    arrays.update(_planes(d32, "drive"))
    arrays.update(_planes(xs, "x"))
    return arrays


def _layer_arrays(lp: dict, prefix: str) -> dict:
    """Flatten an init_s5_layer param dict into fixture tensors."""
    out = {}
    for k, v in lp.items():
        out[f"{prefix}.{k}"] = np.asarray(v).astype(F32)
    return out


def fx_ssm() -> dict:
    """`s5_ssm_apply` (no norm/gate): TI, timescale, TV, bidir, bidir+TV."""
    key = jax.random.PRNGKey(5)
    k_uni, k_bi, k_u, k_dt = jax.random.split(key, 4)
    h, batch, length = 8, 2, 40
    uni = model.init_s5_layer(k_uni, h=h, p=16, j=2)            # P2 = 8
    bi = model.init_s5_layer(k_bi, h=h, p=8, j=1, bidir=True)   # P2 = 4
    u = jax.random.normal(k_u, (batch, length, h), jnp.float32)
    dts = jax.random.uniform(k_dt, (batch, length), jnp.float32, 0.3, 2.5)

    def run2(lp, timescale=1.0, use_dts=False, bidir=False):
        rows = []
        for b in range(batch):
            rows.append(np.asarray(model.s5_ssm_apply(
                lp, u[b], timescale=timescale,
                dts=dts[b] if use_dts else None, bidir=bidir)))
        return np.stack(rows)

    arrays = _layer_arrays(uni, "uni")
    arrays.update(_layer_arrays(bi, "bi"))
    arrays["input.u"] = np.asarray(u).astype(F32)
    arrays["input.dts"] = np.asarray(dts).astype(F32)
    arrays["input.timescale"] = _f32([1.0, 0.5])
    arrays["expect.uni_ti"] = run2(uni).astype(F32)
    arrays["expect.uni_ts"] = run2(uni, timescale=0.5).astype(F32)
    arrays["expect.uni_tv"] = run2(uni, use_dts=True).astype(F32)
    arrays["expect.bi_ti"] = run2(bi, bidir=True).astype(F32)
    arrays["expect.bi_tv"] = run2(bi, use_dts=True, bidir=True).astype(F32)
    return arrays


def fx_layer() -> dict:
    """Full layer: pre-norm → SSM → GELU → weighted-sigmoid gate → residual."""
    key = jax.random.PRNGKey(7)
    k_uni, k_bi, k_u, k_dt, k_ns = jax.random.split(key, 5)
    h, batch, length = 8, 2, 32
    uni = model.init_s5_layer(k_uni, h=h, p=16, j=2)
    bi = model.init_s5_layer(k_bi, h=h, p=8, j=1, bidir=True)
    # non-trivial norm affine so the fixture actually exercises it
    uni["norm_scale"] = 1.0 + 0.1 * jax.random.normal(k_ns, (h,), jnp.float32)
    uni["norm_bias"] = 0.05 * jax.random.normal(k_dt, (h,), jnp.float32)
    u = jax.random.normal(k_u, (batch, length, h), jnp.float32)
    dts = jax.random.uniform(k_dt, (batch, length), jnp.float32, 0.3, 2.5)

    def run(lp, use_dts=False, bidir=False):
        return np.stack([
            np.asarray(model.s5_layer_apply(
                lp, u[b], dts=dts[b] if use_dts else None, bidir=bidir))
            for b in range(batch)
        ])

    arrays = _layer_arrays(uni, "uni")
    arrays.update(_layer_arrays(bi, "bi"))
    arrays["input.u"] = np.asarray(u).astype(F32)
    arrays["input.dts"] = np.asarray(dts).astype(F32)
    arrays["expect.uni_y"] = run(uni).astype(F32)
    arrays["expect.uni_tv_y"] = run(uni, use_dts=True).astype(F32)
    arrays["expect.bi_y"] = run(bi, bidir=True).astype(F32)
    return arrays


def fx_model() -> dict:
    """Classifier logits end-to-end. The param tensors use the Rust
    checkpoint naming (`params.encoder.w`, `params.layers.<i>.*`, ...) so
    the fixture doubles as an `S5Model::from_param_store` checkpoint; the
    extra `input.*`/`expect.*` tensors are ignored by the loader."""
    key = jax.random.PRNGKey(9)
    k_p, k_u = jax.random.split(key)
    d_in, classes, depth, h, p = 3, 4, 2, 8, 8
    batch, length = 3, 24
    params = model.init_classifier(k_p, d_in, classes, depth, h, p, bidir=True)
    u = jax.random.normal(k_u, (batch, length, d_in), jnp.float32)

    arrays = {
        "params.encoder.w": np.asarray(params["encoder"]["w"]).astype(F32),
        "params.encoder.bias": np.asarray(params["encoder"]["bias"]).astype(F32),
        "params.decoder.w": np.asarray(params["decoder"]["w"]).astype(F32),
        "params.decoder.bias": np.asarray(params["decoder"]["bias"]).astype(F32),
    }
    for i, lp in enumerate(params["layers"]):
        arrays.update(_layer_arrays(lp, f"params.layers.{i}"))

    logits = model.batched_classifier_apply(params, u, 1.0, bidir=True)
    logits_ts = model.batched_classifier_apply(params, u, 0.5, bidir=True)
    arrays["input.u"] = np.asarray(u).astype(F32)
    arrays["input.timescale"] = _f32([1.0, 0.5])
    arrays["expect.logits"] = np.asarray(logits).astype(F32)
    arrays["expect.logits_ts"] = np.asarray(logits_ts).astype(F32)
    return arrays


FIXTURES = {
    "fx_hippo.npz": fx_hippo,
    "fx_discretize.npz": fx_discretize,
    "fx_scan_ti.npz": fx_scan_ti,
    "fx_scan_tv.npz": fx_scan_tv,
    "fx_ssm.npz": fx_ssm,
    "fx_layer.npz": fx_layer,
    "fx_model.npz": fx_model,
}


def emit(out_dir: Path = OUT) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = [
        "# Golden parity fixture manifest — generated by",
        "# python/tests/gen_fixtures.py; verified by the",
        "# `manifest_matches_committed_fixtures` test in",
        "# rust/tests/parity_fixtures.rs (crc32 = IEEE reflected, whole file).",
        "#",
        "# file <name> <crc32-hex8> <size-bytes>",
        "# tensor <file>:<name> <d0>x<d1>x...",
    ]
    for fname, build in FIXTURES.items():
        arrays = build()
        path = out_dir / fname
        # np.savez = STORED zip of npy members — what runtime/npz.rs reads
        np.savez(path, **arrays)
        raw = path.read_bytes()
        manifest.append(f"file {fname} {zlib.crc32(raw) & 0xFFFFFFFF:08x} {len(raw)}")
        for name in sorted(arrays):
            shape = "x".join(str(d) for d in arrays[name].shape) or "1"
            manifest.append(f"tensor {fname}:{name} {shape}")
        print(f"wrote {path} ({len(raw)} bytes, {len(arrays)} tensors)")
    (out_dir / "MANIFEST.txt").write_text("\n".join(manifest) + "\n")
    print(f"wrote {out_dir / 'MANIFEST.txt'}")


if __name__ == "__main__":
    emit()
