"""AOT export integrity: HLO text, manifests, and npz stay mutually consistent."""
import os
import zipfile

import numpy as np
import pytest

from compile import aot


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build_preset(out, "quickstart", aot.PRESETS["quickstart"])
    cfg = dict(kind="classifier", d_input=2, classes=3, depth=1, h=8, p=8,
               j=1, length=32, batch=2)
    aot.build_preset(out, "tiny", cfg)
    return out


def _parse_manifest(path):
    inputs, outputs, meta = [], [], {}
    with open(path) as f:
        for line in f:
            parts = line.split()
            if parts[0] == "input":
                inputs.append((int(parts[1]), parts[2], parts[3], parts[4]))
            elif parts[0] == "output":
                outputs.append((int(parts[1]), parts[2], parts[3], parts[4]))
            elif parts[0] == "meta":
                meta[parts[1]] = parts[2]
    return inputs, outputs, meta


def test_hlo_text_is_parseable_entry(exported):
    for name in ("quickstart_fwd", "tiny_fwd", "tiny_train"):
        text = open(os.path.join(exported, f"{name}.hlo.txt")).read()
        assert "ENTRY" in text and "HloModule" in text
        # interchange must be text, not proto bytes
        assert text.isprintable() or "\n" in text


def test_manifest_input_count_matches_hlo_params(exported):
    for name in ("quickstart_fwd", "tiny_fwd", "tiny_train"):
        inputs, outputs, _ = _parse_manifest(
            os.path.join(exported, f"{name}.manifest.txt"))
        text = open(os.path.join(exported, f"{name}.hlo.txt")).read()
        # count parameters of the ENTRY computation only (nested fusions and
        # called computations redeclare their own parameters)
        entry = text[text.index("\nENTRY"):]
        entry = entry[: entry.index("\n}")]
        n_params = entry.count("parameter(")
        assert len(inputs) == n_params, name
        assert len(outputs) >= 1
        assert [i[0] for i in inputs] == list(range(len(inputs)))


def test_npz_names_cover_manifest_params(exported):
    inputs, _, _ = _parse_manifest(
        os.path.join(exported, "tiny_train.manifest.txt"))
    npz = np.load(os.path.join(exported, "tiny_init.npz"))
    param_inputs = [nm for _, nm, _, _ in inputs if nm.startswith("params.")]
    assert set(param_inputs) == set(npz.files)
    # shapes in the manifest match the stored tensors
    shapes = {nm: dims for _, nm, _, dims in inputs}
    for nm in npz.files:
        want = "x".join(str(d) for d in npz[nm].shape) or "-"
        assert shapes[nm] == want, nm


def test_train_manifest_has_adam_state_and_batch(exported):
    inputs, outputs, meta = _parse_manifest(
        os.path.join(exported, "tiny_train.manifest.txt"))
    names = [nm for _, nm, _, _ in inputs]
    assert any(nm.startswith("m.") for nm in names)
    assert any(nm.startswith("v.") for nm in names)
    for scalar in ("lr", "wd", "step"):
        assert scalar in names
    assert "x" in names and "y" in names
    out_names = [nm for _, nm, _, _ in outputs]
    assert "out.3" in out_names and "out.4" in out_names  # loss, acc
    assert meta["classes"] == "3"


def test_npz_is_zipfile_with_npy_entries(exported):
    path = os.path.join(exported, "tiny_init.npz")
    with zipfile.ZipFile(path) as z:
        assert all(n.endswith(".npy") for n in z.namelist())


def test_dtype_tags(exported):
    inputs, _, _ = _parse_manifest(
        os.path.join(exported, "tiny_train.manifest.txt"))
    by_name = {nm: dt for _, nm, dt, _ in inputs}
    assert by_name["y"] == "i32"
    assert by_name["x"] == "f32"
