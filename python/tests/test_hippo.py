"""HiPPO construction invariants (paper §2.3, §4.2, Appendix B.1)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import hippo


@pytest.mark.parametrize("n", [2, 4, 8, 16, 64])
def test_hippo_normal_is_normal(n):
    """HiPPO-N must be a normal matrix: A Aᵀ = Aᵀ A."""
    a = hippo.hippo_normal(n)
    np.testing.assert_allclose(a @ a.T, a.T @ a, atol=1e-10)


@pytest.mark.parametrize("n", [2, 4, 8, 32])
def test_legs_equals_normal_minus_low_rank(n):
    """Eq. (10): A_LegS = A_LegS^Normal − P Pᵀ."""
    a = hippo.hippo_legs(n)
    an = hippo.hippo_normal(n)
    p = hippo.hippo_low_rank(n)
    np.testing.assert_allclose(a, an - np.outer(p, p), atol=1e-10)


@pytest.mark.parametrize("n", [2, 4, 8, 64])
def test_eig_reconstruction(n):
    lam, v = hippo.eig_hippo_normal(n)
    a = hippo.hippo_normal(n)
    np.testing.assert_allclose(v @ np.diag(lam) @ v.conj().T, a, atol=1e-8)
    # V unitary
    np.testing.assert_allclose(v.conj().T @ v, np.eye(n), atol=1e-10)


@pytest.mark.parametrize("n", [2, 4, 8, 64])
def test_eigenvalues_real_part_is_minus_half(n):
    """HiPPO-N = -1/2·I + skew ⇒ all eigenvalues have Re = -1/2 (stability)."""
    lam, _ = hippo.eig_hippo_normal(n)
    np.testing.assert_allclose(lam.real, -0.5 * np.ones(n), atol=1e-10)


def test_eigenvalues_sorted_descending_imag():
    lam, _ = hippo.eig_hippo_normal(16)
    assert (np.diff(lam.imag) <= 1e-12).all()


@pytest.mark.parametrize("p,j", [(8, 1), (16, 2), (32, 4), (64, 8)])
def test_block_diag_init_shapes(p, j):
    lam, v, vinv = hippo.block_diag_hippo_init(p, j, conj_sym=True)
    assert lam.shape == (p // 2,)
    assert v.shape == (p, p // 2)
    assert vinv.shape == (p // 2, p)
    assert (lam.imag > 0).all()          # kept half has Im > 0
    np.testing.assert_allclose(lam.real, -0.5, atol=1e-10)


@pytest.mark.parametrize("p,j", [(8, 2), (16, 4)])
def test_block_diag_no_conj_sym_reconstructs(p, j):
    lam, v, vinv = hippo.block_diag_hippo_init(p, j, conj_sym=False)
    r = p // j
    block = hippo.hippo_normal(r)
    full = np.zeros((p, p))
    for b in range(j):
        full[b * r : (b + 1) * r, b * r : (b + 1) * r] = block
    np.testing.assert_allclose(v @ np.diag(lam) @ vinv, full, atol=1e-8)


def test_block_diag_rejects_bad_divisor():
    with pytest.raises(ValueError):
        hippo.block_diag_hippo_init(10, 3)
    with pytest.raises(ValueError):
        hippo.block_diag_hippo_init(9, 3, conj_sym=True)  # odd block


def test_input_column():
    b = hippo.legs_input_column(4)
    np.testing.assert_allclose(b, np.sqrt([1.0, 3.0, 5.0, 7.0]))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(min_value=2, max_value=48).filter(lambda x: x % 2 == 0))
def test_corollary1_mimo_dynamics_agree_property(n):
    """Corollary 1 sanity: for large N the HiPPO-N ODE with B/2 tracks the
    LegS ODE for MIMO inputs (discretized comparison on a short horizon)."""
    h = 3
    rng = np.random.default_rng(n)
    b_col = hippo.legs_input_column(n)
    b = np.stack([b_col] * h, axis=1)
    a_legs = hippo.hippo_legs(n)
    a_norm = hippo.hippo_normal(n)
    dt = 1e-3
    steps = 200
    u = rng.standard_normal((steps, h)) * 0.1
    x = np.zeros(n)
    xp = np.zeros(n)
    # Implicit Euler: unconditionally stable for both (stiff) systems, so the
    # comparison measures the ODEs rather than integrator blow-up.
    m_legs = np.linalg.inv(np.eye(n) - dt * a_legs)
    m_norm = np.linalg.inv(np.eye(n) - dt * a_norm)
    for k in range(steps):
        x = m_legs @ (x + dt * (b @ u[k]))
        xp = m_norm @ (xp + dt * (0.5 * b @ u[k]))
    # The approximation error decays with N (Theorem 3 of S4D, extended):
    # assert the trajectories stay within a loose envelope that tightens.
    err = np.linalg.norm(x - xp) / (np.linalg.norm(x) + 1e-9)
    assert np.isfinite(err) and err < 5.0
