"""Cross-validate the Rust engine's numeric pipeline against the JAX
reference on the committed golden fixtures — without a Rust toolchain.

``rust_mirror`` below is a literal numpy re-statement of the Rust engine's
op order (f64 drive accumulation rounded to complex64, f64 ZOH
discretization rounded to complex64, a complex64 sequential scan, f64
projection with the conjugate-symmetric 2·Re(·) factor, f32 norm/GELU/
gate). Asserting mirror ≡ fixture-expected within the *same tolerances*
``rust/tests/parity_fixtures.rs`` uses gives the committed tolerances an
offline, re-runnable justification: if the mirror fits, the only way the
real Rust engine can miss is by diverging from its own documented op
order — exactly what the fixture suite exists to catch in CI.

Run from ``python/``:  ``python -m pytest tests/test_fixture_parity.py -q``
(regenerate fixtures first via ``python tests/gen_fixtures.py`` if stale).
"""
from __future__ import annotations

import os
import sys
import zlib
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

FIXDIR = Path(__file__).resolve().parents[2] / "rust" / "tests" / "fixtures"

# The per-module tolerance table — keep in sync with the table at the top
# of rust/tests/parity_fixtures.rs (|got − want| ≤ ATOL + RTOL·|want|).
TOL = {
    "hippo": (1e-5, 1e-6),
    "discretize": (1e-6, 1e-5),
    "scan": (1e-5, 1e-4),
    "ssm": (5e-4, 5e-4),
    "layer": (5e-4, 5e-4),
    "logits": (5e-4, 5e-4),
}


def load(name: str) -> dict:
    path = FIXDIR / name
    if not path.exists():
        pytest.fail(f"{path} missing — run python tests/gen_fixtures.py")
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def assert_close(got, want, module: str, what: str) -> None:
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    atol, rtol = TOL[module]
    err = np.abs(got - want) - rtol * np.abs(want)
    worst = float(err.max()) if err.size else 0.0
    assert worst <= atol, (
        f"{what}: worst |Δ|−rtol·|ref| = {worst:.3e} exceeds atol {atol:.1e}"
    )


# --------------------------------------------------------------------------
# rust_mirror: the engine's op order, in numpy
# --------------------------------------------------------------------------

class rust_mirror:
    """Numpy mirror of rust/src/ssm/{discretize,s5}.rs op order."""

    @staticmethod
    def zoh(lam64: np.ndarray, dt64: np.ndarray):
        """discretize_diag: f64 compute, C32 rounding at the cache edge."""
        lam_bar = np.exp(lam64 * dt64)
        small = np.abs(lam64) < 1e-12
        scale = np.where(small, dt64.astype(np.complex128),
                         (lam_bar - 1.0) / np.where(small, 1.0, lam64))
        return lam_bar, scale

    @staticmethod
    def drive(u: np.ndarray, b_tilde64: np.ndarray) -> np.ndarray:
        """drive_seq: per-element f64 accumulation → to_c32."""
        acc = u.astype(np.float64) @ b_tilde64.T
        return acc.astype(np.complex64)

    @staticmethod
    def scan(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """scan_ti/scan_tv: the complex64 sequential recurrence."""
        length, p2 = b.shape
        a = np.broadcast_to(a.astype(np.complex64), (length, p2))
        b = b.astype(np.complex64)
        x = np.zeros(p2, np.complex64)
        out = np.empty((length, p2), np.complex64)
        for k in range(length):
            x = a[k] * x + b[k]
            out[k] = x
        return out

    @staticmethod
    def project(xs: np.ndarray, ct64: np.ndarray) -> np.ndarray:
        """project_seq: f64 reduction, ×2 (conj-sym), rounded to f32."""
        acc = xs.astype(np.complex128) @ ct64.T
        return (2.0 * acc.real).astype(np.float32)

    @classmethod
    def ssm(cls, lp, u, timescale=1.0, dts=None, bidir=False):
        """apply_ssm (staged planar op order) for one (L, H) sequence."""
        lam = lp["lambda_re"].astype(np.float64) + 1j * lp["lambda_im"].astype(np.float64)
        b64 = lp["b_re"].astype(np.float64) + 1j * lp["b_im"].astype(np.float64)
        c_re, c_im = np.atleast_3d(lp["c_re"]), np.atleast_3d(lp["c_im"])
        if c_re.ndim == 2:
            c_re, c_im = c_re[None], c_im[None]
        c64 = c_re.astype(np.float64) + 1j * c_im.astype(np.float64)
        base_dt = np.exp(lp["log_dt"].astype(np.float64)) * timescale
        length = u.shape[0]

        bu = cls.drive(u, b64)
        if dts is None:
            lam_bar64, scale64 = cls.zoh(lam, base_dt)
            a32 = lam_bar64.astype(np.complex64)
            f32c = scale64.astype(np.complex64)
            xs = cls.scan(a32, bu * f32c)
            a_el, f_el = None, None
        else:
            dt_k = base_dt[None, :] * dts.astype(np.float64)[:, None]
            lam_bar64, scale64 = cls.zoh(lam[None, :], dt_k)
            a_el = lam_bar64.astype(np.complex64)
            f_el = scale64.astype(np.complex64)
            xs = cls.scan(a_el, bu * f_el)
        y = cls.project(xs, c64[0])
        if bidir:
            if dts is None:
                # TI backward: drive_rev folds the f64 scale pre-rounding
                bu_rev = ((u[::-1].astype(np.float64) @ b64.T)
                          * scale64).astype(np.complex64)
                xs_b = cls.scan(a32, bu_rev)[::-1]
            else:
                # TV backward: multipliers/scale reverse with the drive
                bu_rev = cls.drive(u[::-1], b64)
                xs_b = cls.scan(a_el[::-1], bu_rev * f_el[::-1])[::-1]
            y = y + cls.project(xs_b, c64[1])
        return (y + lp["d"] * u).astype(np.float32)

    @staticmethod
    def layer_norm(x, scale, bias):
        x = x.astype(np.float32)
        mu = np.mean(x, axis=-1, keepdims=True, dtype=np.float32)
        var = np.mean((x - mu) ** 2, axis=-1, keepdims=True, dtype=np.float32)
        inv = 1.0 / np.sqrt(var + np.float32(1e-6))
        return (x - mu) * inv * scale + bias

    @staticmethod
    def gelu(x):
        c = np.float32(0.7978845608)
        x = x.astype(np.float32)
        return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x * x * x)))

    @classmethod
    def layer(cls, lp, u, timescale=1.0, dts=None, bidir=False):
        v = cls.layer_norm(u, lp["norm_scale"], lp["norm_bias"])
        y = cls.ssm(lp, v, timescale, dts, bidir)
        g = cls.gelu(y)
        sig = 1.0 / (1.0 + np.exp(-(g @ lp["gate_w"].T.astype(np.float32))))
        return (u + g * sig).astype(np.float32)


def sub(d: dict, prefix: str) -> dict:
    n = len(prefix) + 1
    return {k[n:]: v for k, v in d.items() if k.startswith(prefix + ".")}


# --------------------------------------------------------------------------
# Tests
# --------------------------------------------------------------------------

def test_manifest_matches_files():
    lines = (FIXDIR / "MANIFEST.txt").read_text().splitlines()
    files = {}
    for ln in lines:
        if ln.startswith("file "):
            _, name, crc, size = ln.split()
            files[name] = (int(crc, 16), int(size))
    assert files, "manifest lists no fixture files"
    for name, (crc, size) in files.items():
        raw = (FIXDIR / name).read_bytes()
        assert len(raw) == size, f"{name}: size drifted from manifest"
        assert zlib.crc32(raw) & 0xFFFFFFFF == crc, f"{name}: crc drifted"


def test_hippo_eigenvalues():
    from compile import hippo

    z = load("fx_hippo.npz")
    for i in range(3):
        p, j, conj = z[f"case{i}.meta"]
        lam, _v, _vinv = hippo.block_diag_hippo_init(int(p), int(j), bool(conj))
        assert_close(np.real(lam), z[f"case{i}.lambda_re"], "hippo", f"case{i} re")
        assert_close(np.imag(lam), z[f"case{i}.lambda_im"], "hippo", f"case{i} im")


def test_discretize_mirror():
    z = load("fx_discretize.npz")
    lam = z["lambda_re"].astype(np.float64) + 1j * z["lambda_im"].astype(np.float64)
    for case in ("vec", "scalar"):
        dt = z[f"{case}.dt"].astype(np.float64)
        lam_bar, scale = rust_mirror.zoh(lam, dt)
        assert_close(lam_bar.real, z[f"{case}.lam_bar_re"], "discretize", f"{case} Λ̄re")
        assert_close(lam_bar.imag, z[f"{case}.lam_bar_im"], "discretize", f"{case} Λ̄im")
        assert_close(scale.real, z[f"{case}.scale_re"], "discretize", f"{case} f re")
        assert_close(scale.imag, z[f"{case}.scale_im"], "discretize", f"{case} f im")


@pytest.mark.parametrize("name", ["fx_scan_ti.npz", "fx_scan_tv.npz"])
def test_scan_mirror(name):
    z = load(name)
    a = z["a_re"].astype(np.complex64) + 1j * z["a_im"].astype(np.complex64)
    b = z["drive_re"].astype(np.complex64) + 1j * z["drive_im"].astype(np.complex64)
    xs = rust_mirror.scan(a, b)
    assert_close(xs.real, z["x_re"], "scan", f"{name} re")
    assert_close(xs.imag, z["x_im"], "scan", f"{name} im")


def test_ssm_mirror():
    z = load("fx_ssm.npz")
    uni, bi = sub(z, "uni"), sub(z, "bi")
    u, dts = z["input.u"], z["input.dts"]
    cases = [
        ("expect.uni_ti", uni, dict()),
        ("expect.uni_ts", uni, dict(timescale=0.5)),
        ("expect.uni_tv", uni, dict(use_dts=True)),
        ("expect.bi_ti", bi, dict(bidir=True)),
        ("expect.bi_tv", bi, dict(use_dts=True, bidir=True)),
    ]
    for key, lp, kw in cases:
        got = np.stack([
            rust_mirror.ssm(
                lp, u[b], timescale=kw.get("timescale", 1.0),
                dts=dts[b] if kw.get("use_dts") else None,
                bidir=kw.get("bidir", False))
            for b in range(u.shape[0])
        ])
        assert_close(got, z[key], "ssm", key)


def test_layer_mirror():
    z = load("fx_layer.npz")
    uni, bi = sub(z, "uni"), sub(z, "bi")
    u, dts = z["input.u"], z["input.dts"]
    for key, lp, kw in [
        ("expect.uni_y", uni, dict()),
        ("expect.uni_tv_y", uni, dict(use_dts=True)),
        ("expect.bi_y", bi, dict(bidir=True)),
    ]:
        got = np.stack([
            rust_mirror.layer(
                lp, u[b], dts=dts[b] if kw.get("use_dts") else None,
                bidir=kw.get("bidir", False))
            for b in range(u.shape[0])
        ])
        assert_close(got, z[key], "layer", key)


def test_classifier_mirror():
    z = load("fx_model.npz")
    u = z["input.u"]
    enc_w, enc_b = z["params.encoder.w"], z["params.encoder.bias"]
    dec_w, dec_b = z["params.decoder.w"], z["params.decoder.bias"]
    layers = [sub(z, f"params.layers.{i}") for i in range(2)]
    for key, ts in [("expect.logits", 1.0), ("expect.logits_ts", 0.5)]:
        out = []
        for b in range(u.shape[0]):
            x = (u[b] @ enc_w.T + enc_b).astype(np.float32)
            for lp in layers:
                x = rust_mirror.layer(lp, x, timescale=ts, bidir=True)
            pooled = np.mean(x, axis=0, dtype=np.float32)
            out.append((pooled @ dec_w.T + dec_b).astype(np.float32))
        assert_close(np.stack(out), z[key], "logits", key)


if __name__ == "__main__":
    # `python tests/test_fixture_parity.py` must never silently no-op.
    raise SystemExit(pytest.main([__file__, "-q"] + sys.argv[1:]))
