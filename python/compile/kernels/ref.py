"""Pure-jnp oracles for the S5 scan kernel.

These are the correctness references for the Pallas kernel in
:mod:`compile.kernels.scan`. Two independent implementations are provided:

* :func:`scan_ref_sequential` — the literal recurrence via ``lax.scan``
  (ground truth by construction, O(L) sequential steps);
* :func:`scan_ref_associative` — ``jax.lax.associative_scan`` over the same
  binary operator the paper defines in Appendix H (work-efficient Blelloch
  form, what the official S5 release uses).

The pytest/hypothesis suite asserts three-way agreement: pallas ≡ both refs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "scan_ref_sequential",
    "scan_ref_associative",
    "binary_operator",
    "apply_ssm_ref",
]


def binary_operator(element_i, element_j):
    """The paper's binary associative operator (Appendix H, eq. 34)."""
    a_i, bu_i = element_i
    a_j, bu_j = element_j
    return a_j * a_i, a_j * bu_i + bu_j


def scan_ref_sequential(a: jax.Array, b: jax.Array) -> jax.Array:
    """x_k = a_k ∘ x_{k-1} + b_k via a literal sequential loop."""

    def step(x, ab):
        a_k, b_k = ab
        x = a_k * x + b_k
        return x, x

    x0 = jnp.zeros_like(b[0])
    _, xs = jax.lax.scan(step, x0, (a, b))
    return xs


def scan_ref_associative(a: jax.Array, b: jax.Array) -> jax.Array:
    """x_{1:L} via jax.lax.associative_scan (paper Appendix A, Listing 1)."""
    _, xs = jax.lax.associative_scan(binary_operator, (a, b))
    return xs


def apply_ssm_ref(lambda_bar, b_bar, c_tilde, d, u, conj_sym: bool = True):
    """Reference S5 SSM application (Listing 1's ``apply_ssm``).

    lambda_bar: (P,) complex discretized diagonal state matrix.
    b_bar: (P, H) complex discretized input matrix.
    c_tilde: (H, P) complex output matrix.
    d: (H,) real feedthrough.
    u: (L, H) real input sequence.
    """
    length = u.shape[0]
    lambda_elements = jnp.repeat(lambda_bar[None, ...], length, axis=0)
    bu = u.astype(b_bar.dtype) @ b_bar.T
    xs = scan_ref_associative(lambda_elements, bu)
    scale = 2.0 if conj_sym else 1.0
    ys = scale * (xs @ c_tilde.T).real + d * u
    return ys
