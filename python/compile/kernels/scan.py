"""L1: Pallas kernel for the S5 diagonal-SSM parallel scan.

The paper's compute hot-spot (§2.2, §3.3, Appendix H) is the first-order
linear recurrence with a *diagonal* state matrix,

    x_k = a_k ∘ x_{k-1} + b_k,     a_k, b_k, x_k ∈ ℂ^P,

evaluated over the whole sequence with a parallel scan on the binary
associative operator  (a_i,b_i) • (a_j,b_j) = (a_j∘a_i, a_j∘b_i + b_j).

TPU adaptation (DESIGN.md §Hardware-Adaptation): complex numbers are carried
as planar re/im f32 arrays (the VPU has no complex dtype), the grid tiles the
state dimension P so an (L, P_tile) block of all six operands resides in
VMEM, and the scan itself is the log-depth Hillis–Steele form — every sweep
is a full-width fused multiply-add over the block, which vectorizes onto the
8×128 VPU lanes. The kernel MUST be lowered with ``interpret=True`` here:
the CPU PJRT plugin cannot execute Mosaic custom-calls, and interpret mode
lowers the kernel to plain HLO ops inside the same module as the L2 graph.

Differentiation: ``pallas_call`` has no automatic transpose, so the public
entry point :func:`scan_ssm_planar` carries a ``custom_vjp``. The adjoint of
the recurrence is itself a *reversed* scan with the conjugated, one-step
shifted multipliers (DESIGN.md §5.2):

    p_k = ḡ_k + conj(a_{k+1}) ∘ p_{k+1}        (p_{L+1} = 0)
    ∂L/∂b_k = p_k,          ∂L/∂a_k = conj(x_{k-1}) ∘ p_k   (x_0 = 0)

so the backward pass reuses the exact same kernel on flipped inputs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["scan_ssm_planar", "scan_ssm", "DEFAULT_P_TILE"]

# One (L, P_TILE) f32 block is L·P_TILE·4 bytes; six live operands at
# L=16384, P_TILE=64 is 24 MiB total — per-operand 4 MiB, within the 16 MiB
# VMEM budget once double-buffering splits are accounted for. On the real
# TPU target P_TILE should be a multiple of the 128-lane dimension; here the
# state sizes are small so the tile collapses to P2 when P2 < 64.
DEFAULT_P_TILE = 64


def _scan_kernel(ar_ref, ai_ref, br_ref, bi_ref, xr_ref, xi_ref, *, length: int):
    """Hillis–Steele inclusive scan of the SSM composition operator.

    After ⌈log2 L⌉ sweeps, position k holds the composition of elements
    1..k; its b-component is exactly the state x_k (Appendix H).
    """
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    steps = max(1, math.ceil(math.log2(max(length, 2))))
    offset = 1
    for _ in range(steps):
        # Element k composes with element k-offset (identity (1,0) pad).
        sar = jnp.pad(ar, ((offset, 0), (0, 0)), constant_values=1.0)[:length]
        sai = jnp.pad(ai, ((offset, 0), (0, 0)), constant_values=0.0)[:length]
        sbr = jnp.pad(br, ((offset, 0), (0, 0)), constant_values=0.0)[:length]
        sbi = jnp.pad(bi, ((offset, 0), (0, 0)), constant_values=0.0)[:length]
        # (a',b') = (a∘sa, a∘sb + b) with complex multiply in planar form.
        nar = ar * sar - ai * sai
        nai = ar * sai + ai * sar
        nbr = ar * sbr - ai * sbi + br
        nbi = ar * sbi + ai * sbr + bi
        ar, ai, br, bi = nar, nai, nbr, nbi
        offset *= 2
    xr_ref[...] = br
    xi_ref[...] = bi


def _pick_tile(p: int) -> int:
    tile = min(p, DEFAULT_P_TILE)
    while p % tile != 0:
        tile -= 1
    return tile


@functools.partial(jax.jit, static_argnames=())
def _scan_pallas(ar, ai, br, bi):
    length, p = ar.shape
    tile = _pick_tile(p)
    spec = pl.BlockSpec((length, tile), lambda i: (0, i))
    out_shape = jax.ShapeDtypeStruct((length, p), ar.dtype)
    kernel = functools.partial(_scan_kernel, length=length)
    xr, xi = pl.pallas_call(
        kernel,
        grid=(p // tile,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[out_shape, out_shape],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(ar, ai, br, bi)
    return xr, xi


@jax.custom_vjp
def scan_ssm_planar(ar, ai, br, bi):
    """Inclusive scan of x_k = a_k∘x_{k-1} + b_k in planar complex form.

    Args:
      ar, ai: (L, P) real/imag parts of the per-step diagonal multipliers ā_k.
      br, bi: (L, P) real/imag parts of the driven inputs B̄u_k.
    Returns:
      (xr, xi): (L, P) real/imag parts of the states x_{1:L}.
    """
    return _scan_pallas(ar, ai, br, bi)


def _scan_fwd(ar, ai, br, bi):
    xr, xi = _scan_pallas(ar, ai, br, bi)
    return (xr, xi), (ar, ai, xr, xi)


def _scan_bwd(res, cots):
    ar, ai, xr, xi = res
    gr, gi = cots
    # Multipliers for the adjoint: conj(a) shifted one step *later* in time,
    # then time-reversed. The first element of a scan never multiplies
    # anything (x_0 = 0), so the pad value is irrelevant; use identity.
    car = jnp.concatenate([ar[1:], jnp.ones_like(ar[:1])], axis=0)[::-1]
    cai = jnp.concatenate([-ai[1:], jnp.zeros_like(ai[:1])], axis=0)[::-1]
    pr_rev, pi_rev = _scan_pallas(car, cai, gr[::-1], gi[::-1])
    pr, pi = pr_rev[::-1], pi_rev[::-1]
    # ∂a_k = conj(x_{k-1}) ∘ p_k with x_0 = 0.
    xpr = jnp.concatenate([jnp.zeros_like(xr[:1]), xr[:-1]], axis=0)
    xpi = jnp.concatenate([jnp.zeros_like(xi[:1]), xi[:-1]], axis=0)
    gar = xpr * pr + xpi * pi          # Re(conj(x)·p)
    gai = xpr * pi - xpi * pr          # Im(conj(x)·p)
    return gar, gai, pr, pi


scan_ssm_planar.defvjp(_scan_fwd, _scan_bwd)


def scan_ssm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Complex convenience wrapper over :func:`scan_ssm_planar`.

    a, b: (L, P) complex64 → states (L, P) complex64. Used by tests and the
    reference path; the L2 model calls the planar form directly.
    """
    xr, xi = scan_ssm_planar(
        a.real.astype(jnp.float32),
        a.imag.astype(jnp.float32),
        b.real.astype(jnp.float32),
        b.imag.astype(jnp.float32),
    )
    return xr + 1j * xi
