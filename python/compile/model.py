"""L2: JAX definition of the S5 layer and the paper's deep sequence models.

This module is *build-time only*: :mod:`compile.aot` lowers the jitted
functions defined here to HLO text once, and the Rust coordinator executes
the compiled artifacts. Nothing here runs on the request path.

Contents (paper cross-references):
  * ``init_s5_layer`` / ``s5_layer_apply`` — the S5 layer of §3: conjugate-
    symmetric diagonal parameterization (§3.2), ZOH discretization (eq. 6),
    vector timescales Δ∈ℝ^P (§4.3/D.5), block-diagonal HiPPO-N init (B.1.1),
    parallel scan via the L1 Pallas kernel, GELU + weighted-sigmoid gate
    activation (§G.1), pre-norm residual architecture (§G.2).
  * Ablation switches for Table 6 (Gaussian / antisymmetric / HiPPO-N init ×
    discrete / continuous parameterization) and Table 5 (scalar vs vector Δ).
  * ``classifier_apply`` — encoder → stacked S5 → mean-pool → softmax head
    (§G.1), with bidirectional option (§G.2.2) and a `timescale` input for
    zero-shot sampling-rate transfer (§6.2).
  * ``retrieval_apply`` — the two-tower variant of §G.3.3, eq. (32).
  * ``pendulum_apply`` — CNN image encoder (§G.3.8) → S5 stack consuming
    per-step Δt for irregularly-sampled sequences (§6.3).
  * ``make_*_train_step`` — cross-entropy / MSE losses, gradients through the
    Pallas custom_vjp, AdamW (§G.2.1) with a separate no-weight-decay,
    reduced-LR parameter group for the SSM tensors. The learning rate is a
    runtime input so the Rust trainer owns the cosine schedule.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import hippo
from .kernels.scan import scan_ssm_planar

Params = Dict[str, Any]

# SSM parameter group: no weight decay, scaled learning rate (paper §G.2.1).
SSM_KEYS = ("lambda_re", "lambda_im", "b_re", "b_im", "log_dt")
NO_DECAY_KEYS = SSM_KEYS + ("d", "norm_scale", "norm_bias", "bias", "c_re", "c_im")


# --------------------------------------------------------------------------
# Small building blocks
# --------------------------------------------------------------------------

def _lecun_normal(key, shape):
    fan_in = shape[-1]
    return jax.random.normal(key, shape, dtype=jnp.float32) / math.sqrt(fan_in)


def init_linear(key, d_in: int, d_out: int) -> Params:
    kw, _ = jax.random.split(key)
    return {
        "w": _lecun_normal(kw, (d_out, d_in)),
        "bias": jnp.zeros((d_out,), jnp.float32),
    }


def linear(p: Params, x: jax.Array) -> jax.Array:
    return x @ p["w"].T + p["bias"]


def layer_norm(scale, bias, x, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


# --------------------------------------------------------------------------
# S5 layer
# --------------------------------------------------------------------------

def init_s5_layer(
    key,
    h: int,
    p: int,
    j: int = 1,
    conj_sym: bool = True,
    dt_min: float = 1e-3,
    dt_max: float = 1e-1,
    init: str = "hippo",            # hippo | gaussian | antisymmetric (Table 6)
    parameterization: str = "continuous",  # continuous | discrete (Table 6)
    scalar_dt: bool = False,        # Table 5 ablation: Δ ∈ ℝ instead of ℝ^P
    bidir: bool = False,
) -> Params:
    """Initialize one S5 layer (state size P, features H)."""
    keys = jax.random.split(key, 8)
    p2 = p // 2 if conj_sym else p

    if init == "hippo":
        lam, v, vinv = hippo.block_diag_hippo_init(p, j, conj_sym)
    elif init == "gaussian":
        rng = np.random.default_rng(int(jax.random.randint(keys[6], (), 0, 2**31 - 1)))
        a = rng.normal(size=(p, p)) / math.sqrt(p)
        lam, v = np.linalg.eig(a)
        order = np.argsort(-lam.imag)
        lam, v = lam[order][:p2], v[:, order][:, :p2]
        vinv = np.linalg.pinv(v)
    elif init == "antisymmetric":
        rng = np.random.default_rng(int(jax.random.randint(keys[6], (), 0, 2**31 - 1)))
        m = rng.normal(size=(p, p)) / math.sqrt(p)
        s = (m - m.T) / 2.0
        w, vv = np.linalg.eigh(1j * s)
        lam = -0.5 - 1j * w
        order = np.argsort(-lam.imag)
        lam, v = lam[order][:p2], vv[:, order][:, :p2]
        vinv = v.conj().T
    else:
        raise ValueError(f"unknown init {init!r}")

    # B sampled real then rotated into the eigenbasis: B̃ = V^{-1} B (§B.1.2).
    b = _lecun_normal(keys[0], (p, h))
    b_tilde = jnp.asarray(vinv, jnp.complex64) @ b.astype(jnp.complex64)
    # C sampled complex-normal then rotated: C̃ = C V. Bidirectional models
    # carry a second output matrix applied to the reversed-time scan (§G.2.2).
    n_c = 2 if bidir else 1
    c = (
        jax.random.normal(keys[1], (n_c, h, p), dtype=jnp.float32)
        + 1j * jax.random.normal(keys[2], (n_c, h, p), dtype=jnp.float32)
    ) * math.sqrt(0.5 / p)
    c_tilde = c.astype(jnp.complex64) @ jnp.asarray(v, jnp.complex64)

    n_dt = 1 if scalar_dt else p2
    log_dt = jax.random.uniform(
        keys[3], (n_dt,), jnp.float32,
        minval=math.log(dt_min), maxval=math.log(dt_max),
    )

    lp = {
        "b_re": jnp.real(b_tilde).astype(jnp.float32),
        "b_im": jnp.imag(b_tilde).astype(jnp.float32),
        "c_re": jnp.real(c_tilde).astype(jnp.float32),
        "c_im": jnp.imag(c_tilde).astype(jnp.float32),
        "d": jax.random.normal(keys[4], (h,), dtype=jnp.float32),
        "gate_w": _lecun_normal(keys[5], (h, h)),
        "norm_scale": jnp.ones((h,), jnp.float32),
        "norm_bias": jnp.zeros((h,), jnp.float32),
    }
    if parameterization == "continuous":
        lp["lambda_re"] = jnp.real(jnp.asarray(lam, jnp.complex64)).astype(jnp.float32)
        lp["lambda_im"] = jnp.imag(jnp.asarray(lam, jnp.complex64)).astype(jnp.float32)
        lp["log_dt"] = log_dt
    else:
        # Table 6 "Discrete": learn Λ̄ directly; no Δ, no re-discretization.
        lam_bar = np.exp(np.asarray(lam) * np.exp(np.asarray(log_dt, np.float64).mean()))
        lp["lambda_re"] = jnp.asarray(lam_bar.real, jnp.float32)
        lp["lambda_im"] = jnp.asarray(lam_bar.imag, jnp.float32)
    return lp


def _ssm_scan(lam_bar_c: jax.Array, bu_c: jax.Array) -> jax.Array:
    """Run the Pallas scan on complex (L,P) multipliers/drives."""
    xr, xi = scan_ssm_planar(
        jnp.real(lam_bar_c).astype(jnp.float32),
        jnp.imag(lam_bar_c).astype(jnp.float32),
        jnp.real(bu_c).astype(jnp.float32),
        jnp.imag(bu_c).astype(jnp.float32),
    )
    return xr + 1j * xi


def s5_ssm_apply(
    lp: Params,
    u: jax.Array,                 # (L, H) float32
    timescale: jax.Array | float = 1.0,
    dts: jax.Array | None = None,  # (L,) per-step intervals (irregular mode)
    conj_sym: bool = True,
    parameterization: str = "continuous",
    bidir: bool = False,
) -> jax.Array:
    """Apply the (discretized) S5 SSM to one sequence; returns (L, H)."""
    length = u.shape[0]
    b_tilde = lp["b_re"] + 1j * lp["b_im"]          # (P2, H)
    c_tilde = lp["c_re"] + 1j * lp["c_im"]          # (nc, H, P2)
    bu = u.astype(jnp.complex64) @ b_tilde.T        # (L, P2)

    if parameterization == "continuous":
        lam = lp["lambda_re"] + 1j * lp["lambda_im"]    # (P2,)
        dt = jnp.exp(lp["log_dt"]) * timescale          # (P2,) or (1,)
        if dts is None:
            lam_bar = jnp.exp(lam * dt)                 # ZOH, eq. (6)
            f = (lam_bar - 1.0) / lam
            lam_el = jnp.broadcast_to(lam_bar, (length, lam_bar.shape[-1]))
            drive = f * bu
        else:
            dt_k = dts[:, None] * dt[None, :]           # (L, P2)
            lam_bar = jnp.exp(lam[None, :] * dt_k)
            f = (lam_bar - 1.0) / lam[None, :]
            lam_el = lam_bar
            drive = f * bu
    else:
        # Discrete parameterization: Λ̄ is the learned parameter itself.
        lam_bar = lp["lambda_re"] + 1j * lp["lambda_im"]
        lam_el = jnp.broadcast_to(lam_bar, (length, lam_bar.shape[-1]))
        drive = bu

    xs = _ssm_scan(lam_el, drive)                       # (L, P2)
    scale = 2.0 if conj_sym else 1.0
    y = scale * jnp.real(xs @ c_tilde[0].T)
    if bidir:
        # Backward scan over reversed time. Under irregular sampling the
        # multipliers must reverse *with* the drive so scan step m pairs
        # Λ̄, f and B̃u all taken from source row L−1−m (using the
        # forward-order multipliers here would integrate each reversed
        # input over another step's Δt).
        lam_b = lam_el if dts is None else lam_el[::-1]
        xs_b = _ssm_scan(lam_b, drive[::-1])[::-1]
        y = y + scale * jnp.real(xs_b @ c_tilde[1].T)
    return y + lp["d"] * u


def s5_layer_apply(
    lp: Params,
    u: jax.Array,
    timescale=1.0,
    dts=None,
    conj_sym: bool = True,
    parameterization: str = "continuous",
    bidir: bool = False,
) -> jax.Array:
    """Full S5 layer: pre-norm → SSM → GELU → weighted-sigmoid gate → residual."""
    v = layer_norm(lp["norm_scale"], lp["norm_bias"], u)
    y = s5_ssm_apply(lp, v, timescale, dts, conj_sym, parameterization, bidir)
    g = jax.nn.gelu(y)
    out = g * jax.nn.sigmoid(g @ lp["gate_w"].T)
    return u + out


# --------------------------------------------------------------------------
# Deep models
# --------------------------------------------------------------------------

def init_classifier(
    key,
    d_input: int,
    n_classes: int,
    depth: int,
    h: int,
    p: int,
    j: int = 1,
    bidir: bool = False,
    **layer_kw,
) -> Params:
    keys = jax.random.split(key, depth + 2)
    return {
        "encoder": init_linear(keys[0], d_input, h),
        "layers": [
            init_s5_layer(keys[i + 1], h, p, j, bidir=bidir, **layer_kw)
            for i in range(depth)
        ],
        "decoder": init_linear(keys[depth + 1], h, n_classes),
    }


def classifier_backbone(params, u, timescale=1.0, dts=None, **kw):
    x = linear(params["encoder"], u)
    for lp in params["layers"]:
        x = s5_layer_apply(lp, x, timescale, dts, **kw)
    return x


def classifier_apply(params, u, timescale=1.0, **kw):
    """Single-sequence logits: u (L, d_input) → (n_classes,). Mean-pool head."""
    x = classifier_backbone(params, u, timescale, **kw)
    return linear(params["decoder"], jnp.mean(x, axis=0))


def batched_classifier_apply(params, u, timescale=1.0, **kw):
    """u: (B, L, d_input) → (B, n_classes)."""
    return jax.vmap(lambda s: classifier_apply(params, s, timescale, **kw))(u)


def retrieval_apply(params, u1, u2, timescale=1.0, **kw):
    """Two-tower document matching (§G.3.3): shared encoder, eq. (32) features."""
    x1 = jnp.mean(classifier_backbone(params, u1, timescale, **kw), axis=0)
    x2 = jnp.mean(classifier_backbone(params, u2, timescale, **kw), axis=0)
    feats = jnp.concatenate([x1, x2, x1 * x2, x1 - x2], axis=-1)
    return linear(params["decoder"], feats)


def batched_retrieval_apply(params, u1, u2, timescale=1.0, **kw):
    return jax.vmap(lambda a, b: retrieval_apply(params, a, b, timescale, **kw))(u1, u2)


# ---- Pendulum regressor (§6.3, §G.3.8) -----------------------------------

def init_pendulum_model(key, depth: int, h: int, p: int, j: int = 1, **layer_kw) -> Params:
    keys = jax.random.split(key, depth + 6)
    return {
        "conv1": {  # 1→12 channels, 5x5, pad 2
            "w": _lecun_normal(keys[0], (12, 1, 5, 5)) / 5.0,
            "bias": jnp.zeros((12,), jnp.float32),
        },
        "conv2": {  # 12→12 channels, 3x3, stride 2, pad 1
            "w": _lecun_normal(keys[1], (12, 12, 3, 3)) / 3.0,
            "bias": jnp.zeros((12,), jnp.float32),
        },
        "enc_dense1": init_linear(keys[2], 12 * 3 * 3, h),
        "enc_dense2": init_linear(keys[3], h, h),
        "layers": [
            init_s5_layer(keys[i + 4], h, p, j, **layer_kw) for i in range(depth)
        ],
        "head": init_linear(keys[depth + 4], h, 2),
    }


def _pendulum_encode(params, imgs):
    """imgs (L, 24, 24) → (L, H) via the CRU paper's CNN encoder."""
    x = imgs[:, None, :, :]  # (L, 1, 24, 24)
    x = jax.lax.conv_general_dilated(
        x, params["conv1"]["w"], (1, 1), "SAME") + params["conv1"]["bias"][None, :, None, None]
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")  # (L,12,12,12)
    x = jax.lax.conv_general_dilated(
        x, params["conv2"]["w"], (2, 2), "SAME") + params["conv2"]["bias"][None, :, None, None]
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")  # (L,12,3,3)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(linear(params["enc_dense1"], x))
    return linear(params["enc_dense2"], x)


def pendulum_apply(params, imgs, dts, **kw):
    """imgs (L,24,24), dts (L,) → per-step (L, 2) regression of (sin θ, cos θ)."""
    x = _pendulum_encode(params, imgs)
    for lp in params["layers"]:
        x = s5_layer_apply(lp, x, 1.0, dts, **kw)
    return linear(params["head"], x)


def batched_pendulum_apply(params, imgs, dts, **kw):
    return jax.vmap(lambda i, d: pendulum_apply(params, i, d, **kw))(imgs, dts)


# --------------------------------------------------------------------------
# Losses and the AdamW train step
# --------------------------------------------------------------------------

def cross_entropy_loss(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).squeeze(-1)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return jnp.mean(nll), acc


def _is_ssm_key(path) -> bool:
    last = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return last in SSM_KEYS


def _is_no_decay(path) -> bool:
    last = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return last in NO_DECAY_KEYS


def adamw_update(params, grads, m, v, lr, wd, step, ssm_lr_ratio=0.25,
                 b1=0.9, b2=0.999, eps=1e-8):
    """AdamW with the paper's two parameter groups (§G.2.1).

    SSM tensors (Λ, B̃, Δ) use lr·ssm_lr_ratio and no weight decay; decay is
    decoupled and masked off norm/bias/SSM leaves. ``step`` is 1-based.
    """
    bc1 = 1.0 - jnp.power(b1, step)
    bc2 = 1.0 - jnp.power(b2, step)

    def upd(path, p_, g_, m_, v_):
        m_n = b1 * m_ + (1.0 - b1) * g_
        v_n = b2 * v_ + (1.0 - b2) * g_ * g_
        lr_leaf = lr * (ssm_lr_ratio if _is_ssm_key(path) else 1.0)
        wd_leaf = 0.0 if _is_no_decay(path) else wd
        p_n = p_ - lr_leaf * ((m_n / bc1) / (jnp.sqrt(v_n / bc2) + eps)) \
                 - lr * wd_leaf * p_
        return p_n, m_n, v_n

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p_, g_, m_, v_: upd(path, p_, g_, m_, v_), params, grads, m, v
    )
    new_p = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, new_m, new_v


def make_classifier_train_step(ssm_lr_ratio=0.25, **apply_kw):
    """Returns train_step(params, m, v, lr, wd, step, x, y) → (p', m', v', loss, acc)."""

    def loss_fn(params, x, y):
        logits = batched_classifier_apply(params, x, 1.0, **apply_kw)
        return cross_entropy_loss(logits, y)

    def train_step(params, m, v, lr, wd, step, x, y):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, x, y)
        p2, m2, v2 = adamw_update(params, grads, m, v, lr, wd, step, ssm_lr_ratio)
        return p2, m2, v2, loss, acc

    return train_step


def make_retrieval_train_step(ssm_lr_ratio=0.25, **apply_kw):
    def loss_fn(params, x1, x2, y):
        logits = batched_retrieval_apply(params, x1, x2, 1.0, **apply_kw)
        return cross_entropy_loss(logits, y)

    def train_step(params, m, v, lr, wd, step, x1, x2, y):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, x1, x2, y)
        p2, m2, v2 = adamw_update(params, grads, m, v, lr, wd, step, ssm_lr_ratio)
        return p2, m2, v2, loss, acc

    return train_step


def make_pendulum_train_step(ssm_lr_ratio=0.25, **apply_kw):
    def loss_fn(params, imgs, dts, targets):
        pred = batched_pendulum_apply(params, imgs, dts, **apply_kw)
        mse = jnp.mean((pred - targets) ** 2)
        return mse, mse

    def train_step(params, m, v, lr, wd, step, imgs, dts, targets):
        (loss, mse), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, imgs, dts, targets)
        p2, m2, v2 = adamw_update(params, grads, m, v, lr, wd, step, ssm_lr_ratio)
        return p2, m2, v2, loss, mse

    return train_step


def zeros_like_tree(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)
