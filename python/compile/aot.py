"""AOT export: lower every model graph to HLO text + initial params (.npz).

This is the single build-time entry point (``make artifacts``). For each
preset it emits into ``artifacts/``:

  * ``<preset>_fwd.hlo.txt``      — inference graph
  * ``<preset>_train.hlo.txt``    — fused loss+grad+AdamW train step
  * ``<preset>_init.npz``         — initial parameter tensors (named)
  * ``<preset>_fwd.manifest.txt`` / ``<preset>_train.manifest.txt``
      — argument order, names, dtypes, shapes, and model hyperparameters,
        parsed by ``rust/src/runtime/artifact.rs``.

Interchange is HLO **text**, not a serialized HloModuleProto: jax ≥ 0.5
emits 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Python never runs after this step — the Rust coordinator owns all runtime.
"""
from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Presets: every experiment in the paper maps to one or more of these.
# Sequence lengths are scaled from the paper's (L up to 16,384) to CPU-budget
# equivalents while preserving the ratios that matter (pathx : pathfinder =
# 4x here vs 16x in the paper; documented in DESIGN.md substitutions).
# ---------------------------------------------------------------------------

PRESETS: dict[str, dict] = {
    # name: kind, d_input, classes, depth, H, P, J, L, B, extras
    "quickstart": dict(kind="layer", h=8, p=8, j=1, length=128),
    # Pixel-level image classification (Table 10) + E2E training driver.
    "smnist": dict(kind="classifier", d_input=1, classes=10, depth=4, h=48,
                   p=32, j=4, length=784, batch=16),
    # LRA suite (Tables 1/5/6/7).
    "listops": dict(kind="classifier", d_input=18, classes=10, depth=4, h=32,
                    p=32, j=4, length=512, batch=8, bidir=True),
    "text": dict(kind="classifier", d_input=32, classes=2, depth=4, h=32,
                 p=32, j=4, length=1024, batch=8, bidir=True),
    "retrieval": dict(kind="retrieval", d_input=32, classes=2, depth=3, h=32,
                      p=32, j=4, length=512, batch=4, bidir=True),
    "image": dict(kind="classifier", d_input=1, classes=10, depth=4, h=48,
                  p=32, j=4, length=1024, batch=8, bidir=True),
    "pathfinder": dict(kind="classifier", d_input=1, classes=2, depth=4, h=32,
                       p=32, j=4, length=1024, batch=8, bidir=True),
    "pathx": dict(kind="classifier", d_input=1, classes=2, depth=4, h=24,
                  p=32, j=4, length=4096, batch=4, bidir=True,
                  dt_min=1e-4, dt_max=1e-1),  # longer timescales, §B.1.3
    # Speech commands (Tables 2/8): 35-way, zero-shot resample via timescale.
    "speech": dict(kind="classifier", d_input=1, classes=35, depth=4, h=32,
                   p=32, j=4, length=2048, batch=8, bidir=True),
    # 8 kHz variant: same architecture at half length. fwd graph only — the
    # zero-shot experiment feeds it the *16 kHz-trained* parameters with
    # timescale=2 (parameters are L-independent).
    "speech8k": dict(kind="classifier", d_input=1, classes=35, depth=4, h=32,
                     p=32, j=4, length=1024, batch=8, bidir=True,
                     fwd_only=True),
    # Pendulum regression (Tables 3/9, Figure 3): irregular Δt.
    "pendulum": dict(kind="pendulum", depth=4, h=30, p=16, j=2, length=50,
                     batch=16),
    # Table 5 ablations (on the smnist task for budget reasons).
    "abl5_pn_scalar": dict(kind="classifier", d_input=1, classes=10, depth=4,
                           h=48, p=32, j=1, length=784, batch=16,
                           scalar_dt=True),
    "abl5_pn_vector": dict(kind="classifier", d_input=1, classes=10, depth=4,
                           h=48, p=32, j=1, length=784, batch=16),
    # Table 6 ablations: continuous/discrete × gaussian/antisymmetric/hippo.
    **{
        f"abl6_{par}_{ini}": dict(
            kind="classifier", d_input=18, classes=10, depth=2, h=16, p=16,
            j=1, length=256, batch=16, parameterization=par, init=ini,
        )
        for par in ("continuous", "discrete")
        for ini in ("gaussian", "antisymmetric", "hippo")
    },
}

LAYER_KW_KEYS = ("init", "parameterization", "scalar_dt", "dt_min", "dt_max")
APPLY_KW_KEYS = ("parameterization", "bidir")


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_name(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return ".".join(parts)


def _flat_named(tree, prefix: str):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(f"{prefix}.{_path_name(p)}" if _path_name(p) else prefix, l)
            for p, l in leaves]


def _dtype_tag(x) -> str:
    return {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32"}[np.dtype(x.dtype)]


def write_manifest(path, name, kind, named_in, named_out, meta: dict):
    with open(path, "w") as f:
        f.write(f"artifact {name}\n")
        f.write(f"kind {kind}\n")
        for k, v in meta.items():
            f.write(f"meta {k} {v}\n")
        for i, (nm, leaf) in enumerate(named_in):
            dims = "x".join(str(d) for d in leaf.shape) or "-"
            f.write(f"input {i} {nm} {_dtype_tag(leaf)} {dims}\n")
        for i, (nm, leaf) in enumerate(named_out):
            dims = "x".join(str(d) for d in leaf.shape) or "-"
            f.write(f"output {i} {nm} {_dtype_tag(leaf)} {dims}\n")


def save_params_npz(path, params):
    named = _flat_named(params, "params")
    np.savez(path, **{nm: np.asarray(leaf) for nm, leaf in named})


def export_graph(outdir, name, kind, fn, args_tree, arg_prefixes, meta):
    """Lower fn(*args) and write hlo text + manifest. args given as pytrees."""
    lowered = jax.jit(fn).lower(*args_tree)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(outdir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    named_in = []
    for prefix, tree in zip(arg_prefixes, args_tree):
        named_in.extend(_flat_named(tree, prefix))
    out_shape = jax.eval_shape(fn, *args_tree)
    named_out = _flat_named(out_shape, "out")
    write_manifest(os.path.join(outdir, f"{name}.manifest.txt"),
                   name, kind, named_in, named_out, meta)
    print(f"  wrote {name}: {len(text)} chars, {len(named_in)} inputs, "
          f"{len(named_out)} outputs")


def build_preset(outdir: str, name: str, cfg: dict, fwd_only: bool = False):
    print(f"[aot] preset {name}: {cfg}")
    fwd_only = fwd_only or cfg.get("fwd_only", False)
    key = jax.random.PRNGKey(abs(hash(name)) % (2**31))
    kind = cfg["kind"]
    layer_kw = {k: cfg[k] for k in LAYER_KW_KEYS if k in cfg}
    apply_kw = {k: cfg[k] for k in APPLY_KW_KEYS if k in cfg}
    meta = {k: v for k, v in cfg.items()}

    if kind == "layer":
        lp = model.init_s5_layer(key, cfg["h"], cfg["p"], cfg["j"], **layer_kw)
        u = jnp.zeros((cfg["length"], cfg["h"]), jnp.float32)
        fn = lambda p, x: (model.s5_layer_apply(p, x),)
        export_graph(outdir, f"{name}_fwd", kind, fn, (lp, u),
                     ("params", "u"), meta)
        save_params_npz(os.path.join(outdir, f"{name}_init.npz"), lp)
        return

    if kind in ("classifier", "retrieval"):
        params = model.init_classifier(
            key, cfg["d_input"], cfg["classes"], cfg["depth"], cfg["h"],
            cfg["p"], cfg["j"], bidir=cfg.get("bidir", False), **layer_kw)
        if kind == "retrieval":
            # two-tower head consumes [x1, x2, x1*x2, x1-x2] (§G.3.3, eq. 32)
            params["decoder"] = model.init_linear(
                jax.random.fold_in(key, 99), 4 * cfg["h"], cfg["classes"])
        b, length, d_in = cfg["batch"], cfg["length"], cfg["d_input"]
        ts = jnp.float32(1.0)
        y = jnp.zeros((b,), jnp.int32)
        lr, wd, step = jnp.float32(1e-3), jnp.float32(0.01), jnp.float32(1.0)
        m = model.zeros_like_tree(params)
        v = model.zeros_like_tree(params)
        if kind == "classifier":
            x = jnp.zeros((b, length, d_in), jnp.float32)
            fwd = lambda p, t, xx: (model.batched_classifier_apply(p, xx, t, **apply_kw),)
            export_graph(outdir, f"{name}_fwd", kind, fwd, (params, ts, x),
                         ("params", "timescale", "x"), meta)
            if not fwd_only:
                tstep = model.make_classifier_train_step(**apply_kw)
                export_graph(outdir, f"{name}_train", kind, tstep,
                             (params, m, v, lr, wd, step, x, y),
                             ("params", "m", "v", "lr", "wd", "step", "x", "y"),
                             meta)
        else:
            x1 = jnp.zeros((b, length, d_in), jnp.float32)
            x2 = jnp.zeros((b, length, d_in), jnp.float32)
            fwd = lambda p, t, a, c: (model.batched_retrieval_apply(p, a, c, t, **apply_kw),)
            export_graph(outdir, f"{name}_fwd", kind, fwd, (params, ts, x1, x2),
                         ("params", "timescale", "x1", "x2"), meta)
            if not fwd_only:
                tstep = model.make_retrieval_train_step(**apply_kw)
                export_graph(outdir, f"{name}_train", kind, tstep,
                             (params, m, v, lr, wd, step, x1, x2, y),
                             ("params", "m", "v", "lr", "wd", "step",
                              "x1", "x2", "y"), meta)
        save_params_npz(os.path.join(outdir, f"{name}_init.npz"), params)
        return

    if kind == "pendulum":
        params = model.init_pendulum_model(
            key, cfg["depth"], cfg["h"], cfg["p"], cfg["j"], **layer_kw)
        b, length = cfg["batch"], cfg["length"]
        imgs = jnp.zeros((b, length, 24, 24), jnp.float32)
        dts = jnp.ones((b, length), jnp.float32)
        tgt = jnp.zeros((b, length, 2), jnp.float32)
        lr, wd, step = jnp.float32(1e-3), jnp.float32(0.0), jnp.float32(1.0)
        m = model.zeros_like_tree(params)
        v = model.zeros_like_tree(params)
        fwd = lambda p, i, d: (model.batched_pendulum_apply(p, i, d),)
        export_graph(outdir, f"{name}_fwd", kind, fwd, (params, imgs, dts),
                     ("params", "imgs", "dts"), meta)
        if not fwd_only:
            tstep = model.make_pendulum_train_step()
            export_graph(outdir, f"{name}_train", kind, tstep,
                         (params, m, v, lr, wd, step, imgs, dts, tgt),
                         ("params", "m", "v", "lr", "wd", "step",
                          "imgs", "dts", "targets"), meta)
        save_params_npz(os.path.join(outdir, f"{name}_init.npz"), params)
        return

    raise ValueError(f"unknown kind {kind!r}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="all",
                    help="comma-separated preset names, or 'all' / 'core'")
    ap.add_argument("--fwd-only", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.preset == "all":
        names = list(PRESETS)
    elif args.preset == "core":
        names = ["quickstart", "smnist", "pendulum", "speech"]
    else:
        names = args.preset.split(",")
    for nm in names:
        if nm not in PRESETS:
            sys.exit(f"unknown preset {nm!r}; have {sorted(PRESETS)}")
        build_preset(args.out, nm, PRESETS[nm], fwd_only=args.fwd_only)
    print(f"[aot] done: {len(names)} presets → {args.out}")


if __name__ == "__main__":
    main()
