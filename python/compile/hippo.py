"""HiPPO initialization for S5 (paper §3.2, §4.2, Appendix B.1).

Constructs the HiPPO-LegS matrix, its normal component HiPPO-N
(``A_LegS^Normal``), the low-rank correction, and the block-diagonal
eigen-initialization used by the S5 layer (J HiPPO-N blocks on the
diagonal, Appendix B.1.1 / D.4).

All eigendecompositions exploit the structure HiPPO-N = -1/2·I + S with S
real skew-symmetric: i·S is Hermitian, so we can use the numerically stable
``eigh`` instead of a general non-symmetric eigensolver. This is exactly the
"stably diagonalizable" property the paper relies on (§2.3): the full
HiPPO-LegS matrix does *not* admit such a decomposition.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "hippo_legs",
    "hippo_normal",
    "hippo_low_rank",
    "legs_input_column",
    "eig_hippo_normal",
    "block_diag_hippo_init",
]


def hippo_legs(n: int) -> np.ndarray:
    """The (negated) HiPPO-LegS state matrix, Appendix B.1.1 eq. (7).

    A[n,k] = -(2n+1)^1/2 (2k+1)^1/2 if n > k;  -(n+1) if n == k;  0 if n < k.
    """
    q = np.sqrt(2 * np.arange(n) + 1.0)
    a = -np.tril(np.outer(q, q), -1)
    a -= np.diag(np.arange(n) + 1.0)
    return a


def legs_input_column(n: int) -> np.ndarray:
    """b_LegS with (b)_n = (2n+1)^{1/2}, eq. (8)."""
    return np.sqrt(2 * np.arange(n) + 1.0)


def hippo_normal(n: int) -> np.ndarray:
    """HiPPO-N: the normal component of HiPPO-LegS, eq. (11).

    A^Normal = -1/2·I + S with S skew-symmetric,
    S[n,k] = -(n+1/2)^{1/2}(k+1/2)^{1/2} for n>k and +... for n<k.
    """
    q = np.sqrt(np.arange(n) + 0.5)
    s = np.outer(q, q)
    skew = np.triu(s, 1) - np.tril(s, -1)
    return -0.5 * np.eye(n) + skew


def hippo_low_rank(n: int) -> np.ndarray:
    """P_LegS with (P)_n = (n+1/2)^{1/2}, eq. (12): A_LegS = A^Normal - P P^T."""
    return np.sqrt(np.arange(n) + 0.5)


def eig_hippo_normal(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Stable eigendecomposition of HiPPO-N.

    Returns (lam, V) with ``hippo_normal(n) = V @ diag(lam) @ V^H`` and V
    unitary. Uses eigh on the Hermitian matrix i·S (S = skew part), so the
    decomposition is stable for any n — unlike np.linalg.eig on HiPPO-LegS.
    Eigenvalues are sorted by descending imaginary part so conjugate partners
    occupy mirrored positions (index p and n-1-p).
    """
    a = hippo_normal(n)
    skew = a + 0.5 * np.eye(n)
    # i·S is Hermitian; its (real) eigenvalues w give S = V diag(-i w) V^H.
    w, v = np.linalg.eigh(1j * skew)
    lam = -0.5 - 1j * w
    order = np.argsort(-lam.imag)
    return lam[order], v[:, order]


def block_diag_hippo_init(
    p: int, j: int, conj_sym: bool = True
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Block-diagonal HiPPO-N initialization (Appendix B.1.1, D.4).

    Builds J HiPPO-N blocks of size R = P/J on the diagonal and
    eigendecomposes each block. With ``conj_sym`` (paper §3.2 "Conjugate
    Symmetry") only the R/2 eigenvalues with positive imaginary part are kept
    per block, halving state/parameter count; outputs then use y = 2·Re(C̃x̃).

    Returns ``(lam, V, Vinv)`` where
      * lam: (P2,) complex eigenvalues, P2 = P/2 if conj_sym else P,
      * V:   (P, P2) block-diagonal eigenvector matrix (B̃ = Vinv @ B),
      * Vinv:(P2, P) = V^H restricted to the kept eigenvectors (C̃ = C @ V).
    """
    if p % j != 0:
        raise ValueError(f"latent size P={p} must be divisible by J={j}")
    r = p // j
    if conj_sym and r % 2 != 0:
        raise ValueError(f"block size R={r} must be even under conjugate symmetry")
    lam_r, v_r = eig_hippo_normal(r)
    keep = r // 2 if conj_sym else r
    lam_r, v_r = lam_r[:keep], v_r[:, :keep]  # descending imag ⇒ first half Im>0
    lam = np.concatenate([lam_r] * j)
    p2 = keep * j
    v = np.zeros((p, p2), dtype=np.complex128)
    for b in range(j):
        v[b * r : (b + 1) * r, b * keep : (b + 1) * keep] = v_r
    vinv = v.conj().T
    return lam, v, vinv
